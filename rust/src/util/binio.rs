//! Shared little-endian binary (de)serialization primitives.
//!
//! One set of length-prefixed slice codecs and magic/version header checks
//! used by every on-disk and on-wire format in the crate: the graph
//! snapshot (`graph/io.rs`), the partition shard store (`dist/shard.rs`),
//! model checkpoints (`train/checkpoint.rs`) and the coordinator/worker
//! wire protocol (`dist/proto.rs`). Keeping the codecs in one place means a
//! truncated or mismatched file fails with the same found-vs-expected
//! diagnostics everywhere instead of a bare `UnexpectedEof`.

use anyhow::{bail, Context, Result};
use std::io::{self, Read, Write};
use std::path::{Path, PathBuf};

/// Sanity cap on length prefixes (2^33 elements): a corrupt or adversarial
/// length must not be able to request a multi-terabyte allocation.
const MAX_LEN: u64 = 1 << 33;

/// Render a magic as ASCII where printable, escaped elsewhere (for errors).
fn show_magic(m: &[u8]) -> String {
    m.iter()
        .map(|&b| {
            if (0x20..0x7f).contains(&b) {
                (b as char).to_string()
            } else {
                format!("\\x{b:02x}")
            }
        })
        .collect()
}

/// Write an 8-byte magic tag.
pub fn write_magic(w: &mut impl Write, magic: &[u8; 8]) -> Result<()> {
    w.write_all(magic)?;
    Ok(())
}

/// Read and verify an 8-byte magic tag, reporting found-vs-expected bytes
/// (and distinguishing a truncated header from a wrong one).
pub fn expect_magic(r: &mut impl Read, magic: &[u8; 8], what: &str) -> Result<()> {
    let mut found = [0u8; 8];
    let mut got = 0usize;
    while got < 8 {
        match r.read(&mut found[got..]) {
            Ok(0) => break,
            Ok(k) => got += k,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e).with_context(|| format!("reading {what} magic")),
        }
    }
    if got < 8 {
        bail!(
            "not a {what}: file truncated inside the magic (got {got} of 8 bytes, \
             expected {:?} = {:?})",
            show_magic(magic),
            magic
        );
    }
    if &found != magic {
        bail!(
            "not a {what}: bad magic — expected {:?} ({:?}), found {:?} ({:?})",
            show_magic(magic),
            magic,
            show_magic(&found),
            found
        );
    }
    Ok(())
}

/// Write a u32 format version.
pub fn write_version(w: &mut impl Write, version: u32) -> Result<()> {
    w.write_all(&version.to_le_bytes())?;
    Ok(())
}

/// Read and verify a u32 format version, reporting found-vs-expected.
pub fn expect_version(r: &mut impl Read, expected: u32, what: &str) -> Result<()> {
    let found = read_u32(r).with_context(|| format!("reading {what} version"))?;
    if found != expected {
        bail!("unsupported {what} version: expected {expected}, found {found}");
    }
    Ok(())
}

/// Read a u32 format version that must be one of `supported` (formats
/// that still load their legacy revisions); returns the version found.
pub fn expect_version_in(r: &mut impl Read, supported: &[u32], what: &str) -> Result<u32> {
    let found = read_u32(r).with_context(|| format!("reading {what} version"))?;
    if !supported.contains(&found) {
        bail!("unsupported {what} version: expected one of {supported:?}, found {found}");
    }
    Ok(found)
}

// ---------------------------------------------------------------------------
// Offset/section tracking — so corruption errors say *where*.
// ---------------------------------------------------------------------------

/// A reader that counts every byte consumed, so loaders can report the
/// absolute byte offset and the logical file section a truncation,
/// magic, version, or digest error occurred in — the difference between
/// "UnexpectedEof" and "section `features` (byte offsets 184..4280)".
///
/// Wrap the raw reader once (`Tracked::new(BufReader::new(file))`), then
/// group reads with [`Tracked::section`]; any error inside the closure
/// comes back annotated with the section name and offset span.
pub struct Tracked<R> {
    inner: R,
    offset: u64,
}

impl<R: Read> Tracked<R> {
    pub fn new(inner: R) -> Self {
        Tracked { inner, offset: 0 }
    }

    /// Absolute offset of the next byte to be read.
    pub fn offset(&self) -> u64 {
        self.offset
    }

    /// Access the wrapped reader (e.g. a [`crate::util::hash::HashingReader`]
    /// whose digest the loader needs to reset or collect mid-stream).
    pub fn get_mut(&mut self) -> &mut R {
        &mut self.inner
    }

    /// Run `f` as the named section: on error, the result is annotated
    /// with the section name and the byte span that was being decoded
    /// (the end of the span is where reading stopped).
    pub fn section<T>(
        &mut self,
        name: &str,
        f: impl FnOnce(&mut Self) -> Result<T>,
    ) -> Result<T> {
        let start = self.offset;
        let res = f(self);
        let end = self.offset;
        res.with_context(|| format!("in section `{name}` (byte offsets {start}..{end})"))
    }
}

impl<R: Read> Read for Tracked<R> {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        let n = self.inner.read(buf)?;
        self.offset += n as u64;
        Ok(n)
    }
}

// ---------------------------------------------------------------------------
// Integrity flags shared by the checksummed formats.
// ---------------------------------------------------------------------------

/// Whether a loader should verify stored digests.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Verify {
    /// Check every digest the format carries (the default everywhere).
    Full,
    /// Skip digest verification (`--no-verify`): structural validation
    /// still runs, only the checksum passes are elided. For benchmarks
    /// and emergencies, not for production fleets.
    Skip,
}

/// What integrity checking a successfully loaded artifact actually got.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Integrity {
    /// Current format revision; digests present and verified at load.
    Verified,
    /// Digests present but the caller asked to skip them ([`Verify::Skip`]).
    SkippedByRequest,
    /// Legacy format revision that predates digests — nothing to verify.
    /// Loads are allowed (old stores keep working) but flagged, so
    /// operators know these bytes are on trust.
    LegacyUnverified,
}

impl std::fmt::Display for Integrity {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Integrity::Verified => write!(f, "verified"),
            Integrity::SkippedByRequest => write!(f, "unverified (--no-verify)"),
            Integrity::LegacyUnverified => write!(f, "legacy-unverified"),
        }
    }
}

// ---------------------------------------------------------------------------
// Durable writes — tmp file → fsync → rename → directory fsync.
// ---------------------------------------------------------------------------

/// Sibling temporary path: `name.ext` → `name.ext.tmp` in the same
/// directory, so the commit rename never crosses a filesystem.
pub fn tmp_sibling(path: &Path) -> PathBuf {
    let mut name = path.file_name().map(|n| n.to_os_string()).unwrap_or_else(|| "file".into());
    name.push(".tmp");
    path.with_file_name(name)
}

/// fsync a directory, making previously renamed/created entries durable.
/// A no-op on platforms where directories cannot be opened as files.
pub fn sync_dir(dir: &Path) -> Result<()> {
    #[cfg(unix)]
    std::fs::File::open(dir)
        .and_then(|f| f.sync_all())
        .with_context(|| format!("fsyncing directory {}", dir.display()))?;
    #[cfg(not(unix))]
    let _ = dir;
    Ok(())
}

/// Commit a fully written and fsynced temporary into place: atomic
/// rename onto `path`, then fsync the parent directory so the rename
/// itself survives power loss. The caller must have `sync_all`'d the
/// tmp file's contents first.
pub fn commit_replace(tmp: &Path, path: &Path) -> Result<()> {
    std::fs::rename(tmp, path)
        .with_context(|| format!("renaming {} into place as {}", tmp.display(), path.display()))?;
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            sync_dir(parent)?;
        }
    }
    Ok(())
}

/// Removes a temporary file on drop unless `disarm`ed — the hygiene
/// guard every tmp-file writer arms so a failed write never leaves a
/// stray `*.tmp` behind (and never leaves a *partial* file under the
/// final name, because the final name only ever appears via rename).
pub struct TmpGuard {
    path: PathBuf,
    armed: bool,
}

impl TmpGuard {
    pub fn new(path: PathBuf) -> Self {
        TmpGuard { path, armed: true }
    }

    /// The write committed (renamed away); nothing to clean up.
    pub fn disarm(mut self) {
        self.armed = false;
    }
}

impl Drop for TmpGuard {
    fn drop(&mut self) {
        if self.armed {
            let _ = std::fs::remove_file(&self.path);
        }
    }
}

pub fn write_u8(w: &mut impl Write, x: u8) -> Result<()> {
    w.write_all(&[x])?;
    Ok(())
}

pub fn read_u8(r: &mut impl Read) -> Result<u8> {
    let mut b = [0u8; 1];
    r.read_exact(&mut b)?;
    Ok(b[0])
}

pub fn write_u32(w: &mut impl Write, x: u32) -> Result<()> {
    w.write_all(&x.to_le_bytes())?;
    Ok(())
}

pub fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

pub fn write_u64(w: &mut impl Write, x: u64) -> Result<()> {
    w.write_all(&x.to_le_bytes())?;
    Ok(())
}

pub fn read_u64(r: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

pub fn write_f32(w: &mut impl Write, x: f32) -> Result<()> {
    w.write_all(&x.to_le_bytes())?;
    Ok(())
}

pub fn read_f32(r: &mut impl Read) -> Result<f32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(f32::from_le_bytes(b))
}

pub fn write_f64(w: &mut impl Write, x: f64) -> Result<()> {
    w.write_all(&x.to_le_bytes())?;
    Ok(())
}

pub fn read_f64(r: &mut impl Read) -> Result<f64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(f64::from_le_bytes(b))
}

/// Read a u64 length prefix, rejecting absurd values (corrupt stream).
fn read_len(r: &mut impl Read, what: &str) -> Result<usize> {
    let len = read_u64(r).with_context(|| format!("reading {what} length"))?;
    if len > MAX_LEN {
        bail!("corrupt {what}: length prefix {len} exceeds sanity cap {MAX_LEN}");
    }
    Ok(len as usize)
}

/// Write a length-prefixed byte slice.
pub fn write_bytes(w: &mut impl Write, xs: &[u8]) -> Result<()> {
    write_u64(w, xs.len() as u64)?;
    w.write_all(xs)?;
    Ok(())
}

/// Read a length-prefixed byte slice.
pub fn read_bytes(r: &mut impl Read) -> Result<Vec<u8>> {
    let len = read_len(r, "byte array")?;
    let mut buf = vec![0u8; len];
    r.read_exact(&mut buf).context("reading byte array payload")?;
    Ok(buf)
}

/// Chunk size (in 4-byte elements) of the stack staging buffer the slice
/// writers use: big enough to amortize `write_all` call overhead, small
/// enough to live on the stack — the writers allocate nothing, which is
/// load-bearing for the allocation-free epoch loop (the wire protocol
/// serializes parameter tensors through these on every step).
const WRITE_CHUNK: usize = 1024;

/// Write a length-prefixed u32 slice (little-endian). Heap-allocation-free.
pub fn write_u32s(w: &mut impl Write, xs: &[u32]) -> Result<()> {
    write_u64(w, xs.len() as u64)?;
    let mut buf = [0u8; WRITE_CHUNK * 4];
    for chunk in xs.chunks(WRITE_CHUNK) {
        for (slot, &x) in buf.chunks_exact_mut(4).zip(chunk.iter()) {
            slot.copy_from_slice(&x.to_le_bytes());
        }
        w.write_all(&buf[..chunk.len() * 4])?;
    }
    Ok(())
}

/// Read a length-prefixed u32 slice.
pub fn read_u32s(r: &mut impl Read) -> Result<Vec<u32>> {
    let len = read_len(r, "u32 array")?;
    let mut buf = vec![0u8; len * 4];
    r.read_exact(&mut buf).context("reading u32 array payload")?;
    Ok(buf.chunks_exact(4).map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
}

/// Write a length-prefixed f32 slice (little-endian bit patterns — the
/// round trip is bit-exact, NaNs and signed zeros included).
/// Heap-allocation-free.
pub fn write_f32s(w: &mut impl Write, xs: &[f32]) -> Result<()> {
    write_u64(w, xs.len() as u64)?;
    let mut buf = [0u8; WRITE_CHUNK * 4];
    for chunk in xs.chunks(WRITE_CHUNK) {
        for (slot, &x) in buf.chunks_exact_mut(4).zip(chunk.iter()) {
            slot.copy_from_slice(&x.to_le_bytes());
        }
        w.write_all(&buf[..chunk.len() * 4])?;
    }
    Ok(())
}

/// Read a length-prefixed f32 slice.
pub fn read_f32s(r: &mut impl Read) -> Result<Vec<f32>> {
    let len = read_len(r, "f32 array")?;
    let mut buf = vec![0u8; len * 4];
    r.read_exact(&mut buf).context("reading f32 array payload")?;
    Ok(buf.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_roundtrips() {
        let mut buf = Vec::new();
        write_u8(&mut buf, 7).unwrap();
        write_u32(&mut buf, 0xDEAD_BEEF).unwrap();
        write_u64(&mut buf, u64::MAX - 1).unwrap();
        write_f32(&mut buf, -0.0).unwrap();
        write_f64(&mut buf, f64::MIN_POSITIVE).unwrap();
        let mut r: &[u8] = &buf;
        assert_eq!(read_u8(&mut r).unwrap(), 7);
        assert_eq!(read_u32(&mut r).unwrap(), 0xDEAD_BEEF);
        assert_eq!(read_u64(&mut r).unwrap(), u64::MAX - 1);
        assert_eq!(read_f32(&mut r).unwrap().to_bits(), (-0.0f32).to_bits());
        assert_eq!(read_f64(&mut r).unwrap(), f64::MIN_POSITIVE);
        assert!(r.is_empty());
    }

    #[test]
    fn slice_roundtrips_bit_exact() {
        let mut buf = Vec::new();
        let u = vec![0u32, 1, u32::MAX];
        let f = vec![1.5f32, f32::NAN, -0.0, f32::INFINITY];
        let b = vec![0u8, 255, 42];
        write_u32s(&mut buf, &u).unwrap();
        write_f32s(&mut buf, &f).unwrap();
        write_bytes(&mut buf, &b).unwrap();
        let mut r: &[u8] = &buf;
        assert_eq!(read_u32s(&mut r).unwrap(), u);
        let f2 = read_f32s(&mut r).unwrap();
        assert_eq!(
            f.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            f2.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
        assert_eq!(read_bytes(&mut r).unwrap(), b);
    }

    #[test]
    fn magic_mismatch_reports_found_vs_expected() {
        let mut r: &[u8] = b"WRONGMAG rest";
        let err = expect_magic(&mut r, b"COFREESH", "test shard").unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("COFREESH"), "{msg}");
        assert!(msg.contains("WRONGMAG"), "{msg}");
    }

    #[test]
    fn magic_truncation_is_distinguished() {
        let mut r: &[u8] = b"COF";
        let err = expect_magic(&mut r, b"COFREESH", "test shard").unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("truncated"), "{msg}");
        assert!(msg.contains("3 of 8"), "{msg}");
    }

    #[test]
    fn version_mismatch_reports_both() {
        let mut buf = Vec::new();
        write_version(&mut buf, 3).unwrap();
        let mut r: &[u8] = &buf;
        expect_version(&mut r, 3, "thing").unwrap();
        let mut r2: &[u8] = &buf;
        let err = expect_version(&mut r2, 4, "thing").unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("expected 4") && msg.contains("found 3"), "{msg}");
    }

    #[test]
    fn corrupt_length_is_rejected_not_allocated() {
        let mut buf = Vec::new();
        write_u64(&mut buf, u64::MAX / 2).unwrap();
        let mut r: &[u8] = &buf;
        let err = read_f32s(&mut r).unwrap_err();
        assert!(format!("{err:#}").contains("sanity cap"));
    }

    #[test]
    fn version_in_set_accepts_and_reports() {
        let mut buf = Vec::new();
        write_version(&mut buf, 2).unwrap();
        let mut r: &[u8] = &buf;
        assert_eq!(expect_version_in(&mut r, &[1, 2], "thing").unwrap(), 2);
        let mut r2: &[u8] = &buf;
        let err = expect_version_in(&mut r2, &[3, 4], "thing").unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("[3, 4]") && msg.contains("found 2"), "{msg}");
    }

    #[test]
    fn tracked_reader_names_section_and_offsets() {
        let mut buf = Vec::new();
        write_u32s(&mut buf, &[1, 2, 3]).unwrap();
        write_f32s(&mut buf, &[0.5, 1.5, 2.5, 3.5]).unwrap();
        // Truncate inside the second array's payload.
        buf.truncate(buf.len() - 5);
        let mut r = Tracked::new(&buf[..]);
        let ids = r.section("ids", read_u32s).unwrap();
        assert_eq!(ids, vec![1, 2, 3]);
        assert_eq!(r.offset(), 8 + 12);
        let err = r.section("weights", read_f32s).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("section `weights`"), "{msg}");
        assert!(msg.contains("byte offsets 20.."), "{msg}");
    }

    #[test]
    fn tmp_guard_cleans_up_unless_disarmed() {
        let dir = std::env::temp_dir().join(format!("cofree_binio_guard_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let stray = dir.join("a.tmp");
        std::fs::write(&stray, b"partial").unwrap();
        {
            let _guard = TmpGuard::new(stray.clone());
        }
        assert!(!stray.exists(), "armed guard left the tmp behind");
        let kept = dir.join("b.tmp");
        std::fs::write(&kept, b"done").unwrap();
        TmpGuard::new(kept.clone()).disarm();
        assert!(kept.exists(), "disarmed guard removed a committed file");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn commit_replace_renames_and_survives_missing_parent_sync() {
        let dir = std::env::temp_dir().join(format!("cofree_binio_commit_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let tmp = dir.join("x.bin.tmp");
        let fin = dir.join("x.bin");
        std::fs::write(&tmp, b"payload").unwrap();
        commit_replace(&tmp, &fin).unwrap();
        assert!(!tmp.exists());
        assert_eq!(std::fs::read(&fin).unwrap(), b"payload");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
