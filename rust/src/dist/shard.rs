//! The partition shard store: per-partition on-disk artifacts.
//!
//! `cofree shard --partitions N --out dir/` runs the partitioning pipeline
//! once and writes one self-describing binary file per partition
//! (`shard_0000.bin`, …) plus a human-readable `manifest.json`. A shard
//! holds everything a worker process needs to train on its partition and
//! **nothing else** — the local CSR (as the sorted canonical local edge
//! list it was materialized from), the local→global id table, the DAR
//! weights, and the partition's rows of the feature/label/split arrays —
//! so no worker process ever materializes the full graph. Workers stream
//! the file front-to-back in one pass ([`Shard::read`]); every f32
//! round-trips bit-exactly, which is load-bearing for the cross-process
//! determinism contract.
//!
//! Format (version 2, little-endian, shared [`binio`] header helpers):
//!
//! ```text
//! magic "COFREESH" | u32 version
//! u32 file_digest            (CRC-32C of every byte after this field)
//! u32 n_sections = 6 | u32×6 section digests (CRC-32C of each encoded
//!                            section below, length prefix included)
//! u32 part_id | u32 num_parts
//! u32×4 model (layers, feat_dim, hidden, classes)
//! u64 seed | u64 global_nodes | u64 global_edges
//! u32s global_ids            (len n_local)
//! u32s local edge endpoints  (len 2·m_local, canonical order, u<v sorted)
//! f32s dar weights           (len n_local)
//! f32s features              (len n_local·feat_dim, row-major)
//! u32s labels                (len n_local)
//! bytes split masks          (len n_local)
//! ```
//!
//! The whole-file digest makes a shard self-verifying with one checksum
//! pass at load (`--no-verify` opts out); the per-section digests let
//! `cofree fsck` name which array a corruption landed in. Version 1
//! files (no digest block) still load, flagged `legacy-unverified`.
//!
//! **Durability contract:** every shard is written tmp-file → fsync →
//! rename → directory fsync, and `manifest.json` — which records each
//! file's byte length and full-file CRC — is written the same way,
//! **last**. The manifest is the store's completion marker: a crash at
//! any point leaves either a complete store or a directory with no (or
//! the previous) manifest, never a partial store that passes for done.

use crate::graph::{Dataset, Graph, NodeData};
use crate::partition::VertexCut;
use crate::runtime::ModelConfig;
use crate::train::engine::model_config;
use crate::train::model::ModelKind;
use crate::train::tensorize::{tensorize_subgraph, tensorize_subgraph_ref, NodeDataRef, TrainBatch};
use crate::util::binio::{self, Integrity, Verify};
use crate::util::hash::{crc32c, HashingReader, HashingWriter};
use crate::util::json::{self, Json};
use crate::util::mmap::Mmap;
use anyhow::{bail, ensure, Context, Result};
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::{Path, PathBuf};

pub const SHARD_MAGIC: &[u8; 8] = b"COFREESH";
pub const SHARD_VERSION: u32 = 2;

/// Array section names, in file order — digest bookkeeping and fsck
/// reporting use the same table.
pub const SHARD_SECTIONS: [&str; 6] =
    ["global_ids", "edges", "dar", "features", "labels", "split"];

/// One array section staged for emission (so the digest passes and the
/// write pass serialize identically by construction).
enum Sect<'a> {
    U32s(&'a [u32]),
    F32s(&'a [f32]),
    Bytes(&'a [u8]),
}

impl Sect<'_> {
    fn emit(&self, w: &mut impl Write) -> Result<()> {
        match self {
            Sect::U32s(xs) => binio::write_u32s(w, xs),
            Sect::F32s(xs) => binio::write_f32s(w, xs),
            Sect::Bytes(xs) => binio::write_bytes(w, xs),
        }
    }
}

/// One partition's self-contained training data, as stored on disk.
#[derive(Clone, Debug)]
pub struct Shard {
    pub part_id: usize,
    pub num_parts: usize,
    pub model: ModelConfig,
    /// Dataset seed (provenance; not consumed at train time).
    pub seed: u64,
    /// Full-graph sizes, for manifest cross-checks and sanity reporting.
    pub global_nodes: usize,
    pub global_edges: usize,
    /// Local id → global id (sorted ascending, as materialized).
    pub global_ids: Vec<u32>,
    /// The partition's local topology.
    pub local: Graph,
    /// DAR weight per local node.
    pub dar: Vec<f32>,
    /// The partition's rows of features/labels/splits, locally indexed.
    pub data: NodeData,
}

/// Canonical shard file name for a partition.
pub fn shard_file_name(part_id: usize) -> String {
    format!("shard_{part_id:04}.bin")
}

/// One shard file's write receipt: size and full-file CRC-32C (the
/// digest `manifest.json` records and fsck recomputes from disk).
#[derive(Clone, Copy, Debug)]
pub struct ShardFileInfo {
    pub bytes: u64,
    pub crc32c: u32,
}

impl Shard {
    /// Gather partition `i` of a vertex cut into a shard.
    pub fn from_part(ds: &Dataset, vc: &VertexCut, weights: &[Vec<f32>], i: usize, seed: u64) -> Shard {
        let part = &vc.parts[i];
        let nd = &ds.data;
        let n_local = part.num_nodes();
        let d = nd.dim;
        let mut features = Vec::with_capacity(n_local * d);
        let mut labels = Vec::with_capacity(n_local);
        let mut split = Vec::with_capacity(n_local);
        for &gid in &part.global_ids {
            features.extend_from_slice(nd.feature(gid));
            labels.push(nd.labels[gid as usize]);
            split.push(nd.split[gid as usize]);
        }
        Shard {
            part_id: i,
            num_parts: vc.num_parts,
            model: model_config(ds),
            seed,
            global_nodes: ds.graph.num_nodes(),
            global_edges: ds.graph.num_edges(),
            global_ids: part.global_ids.clone(),
            local: part.local.clone(),
            dar: weights[i].clone(),
            data: NodeData {
                features,
                dim: d,
                labels,
                num_classes: nd.num_classes,
                split,
            },
        }
    }

    /// The scalar header fields (everything between the digest block and
    /// the first array section), in file order.
    fn emit_scalars(&self, w: &mut impl Write) -> Result<()> {
        binio::write_u32(w, self.part_id as u32)?;
        binio::write_u32(w, self.num_parts as u32)?;
        for d in [self.model.layers, self.model.feat_dim, self.model.hidden, self.model.classes] {
            binio::write_u32(w, d as u32)?;
        }
        binio::write_u64(w, self.seed)?;
        binio::write_u64(w, self.global_nodes as u64)?;
        binio::write_u64(w, self.global_edges as u64)?;
        Ok(())
    }

    /// Durably write to `path`: the image goes to a `.tmp` sibling, is
    /// fsynced, renamed into place, and the directory entry fsynced — a
    /// crash at any point leaves either the old file or the new one,
    /// never a torn hybrid, and a failed write cleans up its temporary.
    /// Returns the byte count and full-file CRC for the manifest.
    pub fn write(&self, path: &Path) -> Result<ShardFileInfo> {
        let n_local = self.global_ids.len();
        ensure!(self.dar.len() == n_local, "dar length mismatch");
        ensure!(self.data.labels.len() == n_local, "labels length mismatch");
        ensure!(self.data.split.len() == n_local, "split length mismatch");
        ensure!(self.data.features.len() == n_local * self.data.dim, "features length mismatch");
        let flat: Vec<u32> = self.local.edges().iter().flat_map(|&(u, v)| [u, v]).collect();
        let sections = [
            Sect::U32s(&self.global_ids),
            Sect::U32s(&flat),
            Sect::F32s(&self.dar),
            Sect::F32s(&self.data.features),
            Sect::U32s(&self.data.labels),
            Sect::Bytes(&self.data.split),
        ];
        // Digest pass 1: each encoded section (length prefix included).
        let mut sec_digests = [0u32; 6];
        for (d, s) in sec_digests.iter_mut().zip(&sections) {
            let mut h = HashingWriter::new(std::io::sink());
            s.emit(&mut h)?;
            *d = h.digest();
        }
        // Digest pass 2: the whole-file digest covers every byte after
        // the digest field itself — section count, section digests,
        // scalar header, arrays — so one check at load catches any flip.
        let file_digest = {
            let mut h = HashingWriter::new(std::io::sink());
            binio::write_u32(&mut h, sections.len() as u32)?;
            for d in sec_digests {
                binio::write_u32(&mut h, d)?;
            }
            self.emit_scalars(&mut h)?;
            for s in &sections {
                s.emit(&mut h)?;
            }
            h.digest()
        };
        // Write pass: tmp → fsync → rename → dir fsync.
        let tmp = binio::tmp_sibling(path);
        let guard = binio::TmpGuard::new(tmp.clone());
        let f = std::fs::File::create(&tmp).with_context(|| format!("create {tmp:?}"))?;
        let mut w = HashingWriter::new(BufWriter::new(f));
        binio::write_magic(&mut w, SHARD_MAGIC)?;
        binio::write_version(&mut w, SHARD_VERSION)?;
        binio::write_u32(&mut w, file_digest)?;
        binio::write_u32(&mut w, sections.len() as u32)?;
        for d in sec_digests {
            binio::write_u32(&mut w, d)?;
        }
        self.emit_scalars(&mut w)?;
        for s in &sections {
            s.emit(&mut w)?;
        }
        let (bytes, full_crc) = (w.written(), w.digest());
        let mut bw = w.into_inner();
        bw.flush().with_context(|| format!("flushing {tmp:?}"))?;
        bw.get_ref().sync_all().with_context(|| format!("fsyncing {tmp:?}"))?;
        binio::commit_replace(&tmp, path)?;
        guard.disarm();
        Ok(ShardFileInfo { bytes, crc32c: full_crc })
    }

    /// Stream a shard from `path` with full digest verification.
    pub fn read(path: &Path) -> Result<Shard> {
        Ok(Self::read_with(path, Verify::Full)?.0)
    }

    /// Stream a shard from `path`, rebuilding the local CSR from the sorted
    /// canonical edge list (the same construction the partitioner used, so
    /// the in-memory graph is byte-identical to the one that was written).
    ///
    /// The whole-file digest is verified in the same streaming pass
    /// (format v2); v1 files load flagged [`Integrity::LegacyUnverified`].
    /// Errors name the file section and absolute byte offsets involved.
    pub fn read_with(path: &Path, verify: Verify) -> Result<(Shard, Integrity)> {
        let f = std::fs::File::open(path).with_context(|| format!("open {path:?}"))?;
        let mut r = binio::Tracked::new(HashingReader::new(BufReader::new(f)));
        binio::expect_magic(&mut r, SHARD_MAGIC, "cofree partition shard")
            .with_context(|| format!("reading {path:?}"))?;
        let version = binio::expect_version_in(&mut r, &[1, SHARD_VERSION], "partition shard")?;
        let stored_digest = if version >= 2 {
            let d = binio::read_u32(&mut r).context("reading shard file digest")?;
            // The stored digest covers every byte from here to EOF.
            r.get_mut().reset();
            r.section("digest table", |r| {
                let n = binio::read_u32(r)? as usize;
                ensure!(
                    n == SHARD_SECTIONS.len(),
                    "shard digest table lists {n} sections, expected {}",
                    SHARD_SECTIONS.len()
                );
                for _ in 0..n {
                    binio::read_u32(r)?; // per-section digests (fsck checks these)
                }
                Ok(())
            })?;
            Some(d)
        } else {
            None
        };
        let (part_id, num_parts, model, seed, global_nodes, global_edges) =
            r.section("header", |r| {
                let part_id = binio::read_u32(r)? as usize;
                let num_parts = binio::read_u32(r)? as usize;
                // Shards store dims only — the arrays are
                // architecture-agnostic; the model kind travels in the
                // wire Config frame. The nominal kind here is the
                // default (Sage); consumers compare dims.
                let model = ModelConfig {
                    kind: ModelKind::Sage,
                    layers: binio::read_u32(r)? as usize,
                    feat_dim: binio::read_u32(r)? as usize,
                    hidden: binio::read_u32(r)? as usize,
                    classes: binio::read_u32(r)? as usize,
                };
                let seed = binio::read_u64(r)?;
                let global_nodes = binio::read_u64(r)? as usize;
                let global_edges = binio::read_u64(r)? as usize;
                Ok((part_id, num_parts, model, seed, global_nodes, global_edges))
            })?;
        ensure!(part_id < num_parts, "shard part_id {part_id} out of range {num_parts}");
        let global_ids = r.section("global_ids", binio::read_u32s)?;
        let flat = r.section("edges", binio::read_u32s)?;
        ensure!(flat.len() % 2 == 0, "corrupt local edge array: odd endpoint count");
        let n_local = global_ids.len();
        let edges: Vec<(u32, u32)> = flat.chunks_exact(2).map(|c| (c[0], c[1])).collect();
        check_edges(&edges, n_local)?;
        let local = Graph::from_sorted_edges(n_local, edges);
        let dar = r.section("dar", binio::read_f32s)?;
        let features = r.section("features", binio::read_f32s)?;
        let labels = r.section("labels", binio::read_u32s)?;
        let split = r.section("split", binio::read_bytes)?;
        // Trailing bytes would silently escape the digest: refuse them.
        let mut probe = [0u8; 1];
        let extra = r.read(&mut probe).with_context(|| format!("probing end of {path:?}"))?;
        ensure!(
            extra == 0,
            "corrupt shard: trailing bytes after split masks at byte offset {}",
            r.offset() - 1
        );
        ensure!(dar.len() == n_local, "dar length {} != {n_local}", dar.len());
        ensure!(labels.len() == n_local, "labels length {} != {n_local}", labels.len());
        ensure!(split.len() == n_local, "split length {} != {n_local}", split.len());
        ensure!(
            features.len() == n_local * model.feat_dim,
            "features length {} != n_local {n_local} × feat_dim {}",
            features.len(),
            model.feat_dim
        );
        let integrity = match (stored_digest, verify) {
            (Some(want), Verify::Full) => {
                let got = r.get_mut().digest();
                ensure!(
                    got == want,
                    "shard file digest mismatch in {path:?}: stored {want:#010x}, \
                     computed {got:#010x} — the bytes are corrupt"
                );
                Integrity::Verified
            }
            (Some(_), Verify::Skip) => Integrity::SkippedByRequest,
            (None, _) => Integrity::LegacyUnverified,
        };
        Ok((
            Shard {
                part_id,
                num_parts,
                model,
                seed,
                global_nodes,
                global_edges,
                global_ids,
                local,
                dar,
                data: NodeData {
                    features,
                    dim: model.feat_dim,
                    labels,
                    num_classes: model.classes,
                    split,
                },
            },
            integrity,
        ))
    }

    /// Tensorize this shard at a padded shape — produces the exact batch
    /// `tensorize_partition` builds from the full graph for this partition
    /// (the id map is the identity over local rows, and the stored rows
    /// were gathered with the same global ids).
    pub fn tensorize(&self, n_pad: usize, e_pad: usize) -> Result<TrainBatch> {
        let ids: Vec<u32> = (0..self.global_ids.len() as u32).collect();
        tensorize_subgraph(&ids, &self.local, &self.data, &self.dar, n_pad, e_pad)
    }
}

// ---------------------------------------------------------------------------
// Zero-copy load path.
// ---------------------------------------------------------------------------

/// Byte range of one array inside a mapped shard file.
type ByteRange = (usize, usize);

/// The stored digest block of a v2+ shard image.
#[derive(Clone, Copy, Debug)]
struct ShardDigests {
    /// Whole-file digest (covers `body_start..EOF`).
    file: u32,
    /// Offset the whole-file digest's coverage starts at (the byte
    /// right after the digest field).
    body_start: usize,
    /// Per-section digests, [`SHARD_SECTIONS`] order.
    sections: [u32; 6],
}

/// Parsed header + array ranges of a shard byte image (shared validation
/// for the zero-copy path; the layout is the one documented at the top of
/// this module and written by [`Shard::write`]).
struct ParsedShard {
    version: u32,
    digests: Option<ShardDigests>,
    part_id: usize,
    num_parts: usize,
    model: ModelConfig,
    seed: u64,
    global_nodes: usize,
    global_edges: usize,
    n_local: usize,
    global_ids: ByteRange,
    edges: ByteRange,
    dar: ByteRange,
    features: ByteRange,
    labels: ByteRange,
    split: ByteRange,
}

impl ParsedShard {
    /// Section byte spans *including* each section's 8-byte length
    /// prefix — the exact spans the per-section digests were computed
    /// over — in [`SHARD_SECTIONS`] order.
    fn section_spans(&self) -> [ByteRange; 6] {
        [self.global_ids, self.edges, self.dar, self.features, self.labels, self.split]
            .map(|(start, end)| (start - 8, end))
    }
}

/// Validate a decoded local edge list: strictly sorted, unique, `u < v`,
/// endpoints in range. Shared by every load path and fsck.
fn check_edges(edges: &[(u32, u32)], n_local: usize) -> Result<()> {
    for (k, &(u, v)) in edges.iter().enumerate() {
        ensure!(
            u < v && (v as usize) < n_local,
            "corrupt local edge {k}: ({u},{v}) with n_local {n_local}"
        );
        if k > 0 {
            ensure!(edges[k - 1] < edges[k], "local edges not sorted/unique at {k}");
        }
    }
    Ok(())
}

/// Decode a little-endian endpoint-pair byte image into an edge list and
/// validate it (the byte length was already checked to be a multiple of
/// 8 by the layout parse).
fn decode_checked_edges(flat: &[u8], n_local: usize) -> Result<Vec<(u32, u32)>> {
    let edges: Vec<(u32, u32)> = flat
        .chunks_exact(8)
        .map(|c| {
            (
                u32::from_le_bytes([c[0], c[1], c[2], c[3]]),
                u32::from_le_bytes([c[4], c[5], c[6], c[7]]),
            )
        })
        .collect();
    check_edges(&edges, n_local)?;
    Ok(edges)
}

/// Read a `u64`-length-prefixed array's byte range off the cursor.
fn take_array(
    bytes: &[u8],
    r: &mut &[u8],
    elem: usize,
    what: &str,
) -> Result<(usize, ByteRange)> {
    let at = bytes.len() - r.len();
    let len = binio::read_u64(r)
        .with_context(|| format!("reading {what} length at byte offset {at}"))?
        as usize;
    let nbytes = len
        .checked_mul(elem)
        .with_context(|| format!("corrupt {what}: length {len} at byte offset {at} overflows"))?;
    ensure!(
        r.len() >= nbytes,
        "truncated shard: {what} at byte offset {at} wants {nbytes} bytes, {} remain",
        r.len()
    );
    let start = bytes.len() - r.len();
    *r = &r[nbytes..];
    Ok((len, (start, start + nbytes)))
}

fn parse_shard_bytes(bytes: &[u8], path: &Path) -> Result<ParsedShard> {
    let mut r: &[u8] = bytes;
    binio::expect_magic(&mut r, SHARD_MAGIC, "cofree partition shard")
        .with_context(|| format!("reading {path:?}"))?;
    let version = binio::expect_version_in(&mut r, &[1, SHARD_VERSION], "partition shard")?;
    let digests = if version >= 2 {
        let file = binio::read_u32(&mut r).context("reading shard file digest")?;
        let body_start = bytes.len() - r.len();
        let n = binio::read_u32(&mut r).context("reading shard section count")? as usize;
        ensure!(
            n == SHARD_SECTIONS.len(),
            "shard digest table lists {n} sections, expected {}",
            SHARD_SECTIONS.len()
        );
        let mut sections = [0u32; 6];
        for d in sections.iter_mut() {
            *d = binio::read_u32(&mut r).context("reading shard section digest")?;
        }
        Some(ShardDigests { file, body_start, sections })
    } else {
        None
    };
    let part_id = binio::read_u32(&mut r)? as usize;
    let num_parts = binio::read_u32(&mut r)? as usize;
    let model = ModelConfig {
        kind: ModelKind::Sage,
        layers: binio::read_u32(&mut r)? as usize,
        feat_dim: binio::read_u32(&mut r)? as usize,
        hidden: binio::read_u32(&mut r)? as usize,
        classes: binio::read_u32(&mut r)? as usize,
    };
    let seed = binio::read_u64(&mut r)?;
    let global_nodes = binio::read_u64(&mut r)? as usize;
    let global_edges = binio::read_u64(&mut r)? as usize;
    ensure!(part_id < num_parts, "shard part_id {part_id} out of range {num_parts}");
    let (n_local, global_ids) = take_array(bytes, &mut r, 4, "id table")?;
    let (flat_len, edges) = take_array(bytes, &mut r, 4, "local edges")?;
    ensure!(flat_len % 2 == 0, "corrupt local edge array: odd endpoint count");
    let (dar_len, dar) = take_array(bytes, &mut r, 4, "dar weights")?;
    let (feat_len, features) = take_array(bytes, &mut r, 4, "features")?;
    let (labels_len, labels) = take_array(bytes, &mut r, 4, "labels")?;
    let (split_len, split) = take_array(bytes, &mut r, 1, "split masks")?;
    ensure!(r.is_empty(), "corrupt shard: {} trailing bytes", r.len());
    ensure!(dar_len == n_local, "dar length {dar_len} != {n_local}");
    ensure!(labels_len == n_local, "labels length {labels_len} != {n_local}");
    ensure!(split_len == n_local, "split length {split_len} != {n_local}");
    ensure!(
        feat_len == n_local * model.feat_dim,
        "features length {feat_len} != n_local {n_local} × feat_dim {}",
        model.feat_dim
    );
    Ok(ParsedShard {
        version,
        digests,
        part_id,
        num_parts,
        model,
        seed,
        global_nodes,
        global_edges,
        n_local,
        global_ids,
        edges,
        dar,
        features,
        labels,
        split,
    })
}

/// Verify a parsed shard image's stored digests: the whole-file digest
/// always (one CRC pass), the per-section digests when `localize` is set
/// (fsck uses this to name the corrupt array). Returns how many section
/// digests were checked; 0 for legacy v1 images, which have none.
fn verify_shard_digests(bytes: &[u8], parsed: &ParsedShard, localize: bool) -> Result<usize> {
    let Some(d) = parsed.digests else {
        return Ok(0);
    };
    let got = crc32c(&bytes[d.body_start..]);
    ensure!(
        got == d.file,
        "shard file digest mismatch: stored {:#010x}, computed {got:#010x} — the bytes are corrupt",
        d.file
    );
    if !localize {
        return Ok(0);
    }
    let mut checked = 0usize;
    for ((span, want), name) in
        parsed.section_spans().iter().zip(d.sections).zip(SHARD_SECTIONS)
    {
        let got = crc32c(&bytes[span.0..span.1]);
        ensure!(
            got == want,
            "shard section `{name}` digest mismatch (byte offsets {}..{}): \
             stored {want:#010x}, computed {got:#010x}",
            span.0,
            span.1
        );
        checked += 1;
    }
    Ok(checked)
}

/// Alignment-checked reinterpretation of a little-endian byte range as a
/// 4-byte-element slice. Sound for any `T` whose every bit pattern is
/// valid (u32, f32); the caller guarantees the target is little-endian.
fn reinterpret_4byte<T>(bytes: &[u8]) -> Result<&[T]> {
    // SAFETY: u32/f32 accept all bit patterns; align_to itself verifies
    // the pointer alignment and we refuse any remainder.
    let (pre, mid, post) = unsafe { bytes.align_to::<T>() };
    ensure!(
        pre.is_empty() && post.is_empty(),
        "mapped shard array is not 4-byte aligned (offset drift?)"
    );
    Ok(mid)
}

/// Array storage of a [`MappedShard`]: borrowed straight out of the page
/// cache when the platform allows, owned copies otherwise.
enum ShardArrays {
    Mapped {
        map: Mmap,
        global_ids: ByteRange,
        dar: ByteRange,
        features: ByteRange,
        labels: ByteRange,
        split: ByteRange,
    },
    Owned {
        global_ids: Vec<u32>,
        dar: Vec<f32>,
        features: Vec<f32>,
        labels: Vec<u32>,
        split: Vec<u8>,
    },
}

/// A shard opened through the zero-copy load path: the file is mmapped,
/// the header and array layout are validated in place, and the id table,
/// DAR weights, feature rows, labels and split masks are **borrowed from
/// the mapping** — a worker starts without deserializing a private copy
/// of any of them (the local CSR is rebuilt, which is graph construction,
/// not a copy). On big-endian targets, or if the mapping cannot be
/// aligned, the loader transparently falls back to the streamed
/// [`Shard::read`] copy — byte-identical contents either way
/// (property-tested below).
///
/// Shard files are written-once artifacts; as with any mmap reader,
/// truncating one while a worker has it mapped is undefined behavior at
/// the file level (the process may fault). Don't rewrite a live store.
pub struct MappedShard {
    pub part_id: usize,
    pub num_parts: usize,
    pub model: ModelConfig,
    /// Dataset seed (provenance; not consumed at train time).
    pub seed: u64,
    pub global_nodes: usize,
    pub global_edges: usize,
    /// The partition's local topology, rebuilt from the stored sorted
    /// canonical edge list with the same `from_sorted_edges` construction
    /// the partitioner used.
    pub local: Graph,
    integrity: Integrity,
    arrays: ShardArrays,
}

impl MappedShard {
    /// Open `path` through the zero-copy path (with portable fallback),
    /// verifying the whole-file digest.
    pub fn open(path: &Path) -> Result<MappedShard> {
        Self::open_with(path, Verify::Full)
    }

    /// Open `path`, controlling digest verification: [`Verify::Full`]
    /// runs one CRC pass over the mapping before any array is trusted;
    /// [`Verify::Skip`] (the `--no-verify` path) trusts the bytes as-is.
    /// Legacy v1 files carry no digest and load flagged
    /// [`Integrity::LegacyUnverified`] either way.
    pub fn open_with(path: &Path, verify: Verify) -> Result<MappedShard> {
        let map = Mmap::open(path)?;
        let parsed = parse_shard_bytes(map.bytes(), path)?;
        let integrity = match (parsed.digests, verify) {
            (Some(_), Verify::Full) => {
                verify_shard_digests(map.bytes(), &parsed, false)
                    .with_context(|| format!("verifying {path:?}"))?;
                Integrity::Verified
            }
            (Some(_), Verify::Skip) => Integrity::SkippedByRequest,
            (None, _) => Integrity::LegacyUnverified,
        };
        // Decode the edge list (endian-safe per-element reads) and rebuild
        // the CSR exactly like Shard::read does.
        let flat = &map.bytes()[parsed.edges.0..parsed.edges.1];
        let n_local = parsed.n_local;
        let edges = decode_checked_edges(flat, n_local)?;
        let local = Graph::from_sorted_edges(n_local, edges);
        // Zero-copy needs a little-endian target (the arrays are stored LE
        // and reinterpreted in place) and 4-byte-aligned ranges.
        let zero_copy = cfg!(target_endian = "little")
            && reinterpret_4byte::<u32>(&map.bytes()[parsed.global_ids.0..parsed.global_ids.1])
                .is_ok()
            && reinterpret_4byte::<f32>(&map.bytes()[parsed.dar.0..parsed.dar.1]).is_ok()
            && reinterpret_4byte::<f32>(&map.bytes()[parsed.features.0..parsed.features.1])
                .is_ok()
            && reinterpret_4byte::<u32>(&map.bytes()[parsed.labels.0..parsed.labels.1]).is_ok();
        let arrays = if zero_copy {
            ShardArrays::Mapped {
                map,
                global_ids: parsed.global_ids,
                dar: parsed.dar,
                features: parsed.features,
                labels: parsed.labels,
                split: parsed.split,
            }
        } else {
            // Portable fallback: one streamed read, owned arrays (the
            // digest was already verified — or skipped — above).
            let shard = Shard::read_with(path, Verify::Skip)?.0;
            ShardArrays::Owned {
                global_ids: shard.global_ids,
                dar: shard.dar,
                features: shard.data.features,
                labels: shard.data.labels,
                split: shard.data.split,
            }
        };
        Ok(MappedShard {
            part_id: parsed.part_id,
            num_parts: parsed.num_parts,
            model: parsed.model,
            seed: parsed.seed,
            global_nodes: parsed.global_nodes,
            global_edges: parsed.global_edges,
            local,
            integrity,
            arrays,
        })
    }

    /// How the bytes backing this shard were vetted at open.
    pub fn integrity(&self) -> Integrity {
        self.integrity
    }

    /// Whether the arrays are truly borrowed from the mapping.
    pub fn is_zero_copy(&self) -> bool {
        matches!(&self.arrays, ShardArrays::Mapped { map, .. } if map.is_mapped())
    }

    pub fn n_local(&self) -> usize {
        self.global_ids().len()
    }

    /// Local id → global id (sorted ascending, as materialized).
    pub fn global_ids(&self) -> &[u32] {
        match &self.arrays {
            ShardArrays::Mapped { map, global_ids, .. } => {
                reinterpret_4byte(&map.bytes()[global_ids.0..global_ids.1])
                    .expect("alignment verified at open")
            }
            ShardArrays::Owned { global_ids, .. } => global_ids,
        }
    }

    /// DAR weight per local node.
    pub fn dar(&self) -> &[f32] {
        match &self.arrays {
            ShardArrays::Mapped { map, dar, .. } => {
                reinterpret_4byte(&map.bytes()[dar.0..dar.1]).expect("alignment verified at open")
            }
            ShardArrays::Owned { dar, .. } => dar,
        }
    }

    /// The partition's feature rows, row-major `[n_local, feat_dim]`.
    pub fn features(&self) -> &[f32] {
        match &self.arrays {
            ShardArrays::Mapped { map, features, .. } => {
                reinterpret_4byte(&map.bytes()[features.0..features.1])
                    .expect("alignment verified at open")
            }
            ShardArrays::Owned { features, .. } => features,
        }
    }

    /// Class id per local node.
    pub fn labels(&self) -> &[u32] {
        match &self.arrays {
            ShardArrays::Mapped { map, labels, .. } => {
                reinterpret_4byte(&map.bytes()[labels.0..labels.1])
                    .expect("alignment verified at open")
            }
            ShardArrays::Owned { labels, .. } => labels,
        }
    }

    /// Split mask per local node (0 train, 1 val, 2 test).
    pub fn split(&self) -> &[u8] {
        match &self.arrays {
            ShardArrays::Mapped { map, split, .. } => &map.bytes()[split.0..split.1],
            ShardArrays::Owned { split, .. } => split,
        }
    }

    /// Tensorize straight off the mapped arrays — produces the exact batch
    /// [`Shard::tensorize`] (and therefore the in-process engine) builds
    /// for this partition.
    pub fn tensorize(&self, n_pad: usize, e_pad: usize) -> Result<TrainBatch> {
        let ids: Vec<u32> = (0..self.n_local() as u32).collect();
        let nd = NodeDataRef {
            features: self.features(),
            dim: self.model.feat_dim,
            labels: self.labels(),
            num_classes: self.model.classes,
            split: self.split(),
        };
        tensorize_subgraph_ref(&ids, &self.local, nd, self.dar(), n_pad, e_pad)
    }

    /// Materialize an owned [`Shard`] (copies — used by parity tests).
    pub fn to_shard(&self) -> Shard {
        Shard {
            part_id: self.part_id,
            num_parts: self.num_parts,
            model: self.model,
            seed: self.seed,
            global_nodes: self.global_nodes,
            global_edges: self.global_edges,
            global_ids: self.global_ids().to_vec(),
            local: self.local.clone(),
            dar: self.dar().to_vec(),
            data: NodeData {
                features: self.features().to_vec(),
                dim: self.model.feat_dim,
                labels: self.labels().to_vec(),
                num_classes: self.model.classes,
                split: self.split().to_vec(),
            },
        }
    }
}

/// One row of a shard store's write receipt (and of `manifest.json`).
#[derive(Clone, Debug)]
pub struct ShardFileRecord {
    pub name: String,
    pub bytes: u64,
    /// Full-file CRC-32C, recomputable from the raw bytes on disk.
    pub crc32c: u32,
}

/// Aggregate output of [`write_shards`].
#[derive(Clone, Debug)]
pub struct ShardSetStats {
    /// Per-shard write receipts, part order.
    pub files: Vec<ShardFileRecord>,
    pub total_bytes: u64,
}

/// Write every partition of `vc` as a shard under `dir` (created if
/// missing), plus `manifest.json`.
///
/// Every file goes through the durable tmp → fsync → rename path, and the
/// manifest is written **last** — it is the store's completion marker, so
/// a crash mid-write can never leave a partial store that passes for
/// complete ([`read_manifest`] and fsck both treat a missing manifest as
/// "incomplete store").
pub fn write_shards(
    ds: &Dataset,
    vc: &VertexCut,
    weights: &[Vec<f32>],
    seed: u64,
    dir: &Path,
) -> Result<ShardSetStats> {
    ensure!(weights.len() == vc.parts.len(), "one weight table per part");
    std::fs::create_dir_all(dir).with_context(|| format!("create {dir:?}"))?;
    let mut files = Vec::with_capacity(vc.parts.len());
    let mut total_bytes = 0u64;
    for i in 0..vc.parts.len() {
        let shard = Shard::from_part(ds, vc, weights, i, seed);
        let name = shard_file_name(i);
        let info = shard.write(&dir.join(&name))?;
        total_bytes += info.bytes;
        files.push(ShardFileRecord { name, bytes: info.bytes, crc32c: info.crc32c });
    }
    let stats = ShardSetStats { files, total_bytes };
    write_manifest(ds, vc, seed, dir, &stats)?;
    Ok(stats)
}

/// Write `manifest.json` — the store's completion marker and integrity
/// index: one row per shard with its byte length and full-file CRC-32C.
/// Written through the same durable tmp → fsync → rename path as the
/// shards themselves, and always **after** every shard file is committed.
fn write_manifest(
    ds: &Dataset,
    vc: &VertexCut,
    seed: u64,
    dir: &Path,
    stats: &ShardSetStats,
) -> Result<()> {
    let part_sizes: Vec<(usize, usize)> =
        vc.parts.iter().map(|p| (p.num_nodes(), p.num_edges())).collect();
    let json = render_manifest(
        &ds.name,
        seed,
        vc.num_parts,
        &model_config(ds),
        ds.graph.num_nodes(),
        ds.graph.num_edges(),
        stats,
        &part_sizes,
    );
    commit_manifest(dir, &json)
}

/// Render `manifest.json` exactly as [`write_shards`] emits it. Shared with
/// the streaming materializer ([`crate::ingest`]) so the two pipelines'
/// manifests are byte-identical by construction. `part_sizes` is one
/// `(nodes, edges)` pair per partition, part order.
pub(crate) fn render_manifest(
    dataset: &str,
    seed: u64,
    num_parts: usize,
    model: &ModelConfig,
    graph_nodes: usize,
    graph_edges: usize,
    stats: &ShardSetStats,
    part_sizes: &[(usize, usize)],
) -> String {
    let mut shards = String::new();
    for (i, rec) in stats.files.iter().enumerate() {
        if i > 0 {
            shards.push_str(",\n    ");
        }
        let (nodes, edges) = part_sizes[i];
        shards.push_str(&format!(
            "{{\"file\": \"{}\", \"part_id\": {i}, \"nodes\": {nodes}, \"edges\": {edges}, \"bytes\": {}, \"crc32c\": {}}}",
            rec.name, rec.bytes, rec.crc32c
        ));
    }
    format!(
        "{{\n  \"format\": \"cofree-shards-v{SHARD_VERSION}\",\n  \"dataset\": \"{dataset}\",\n  \"seed\": {seed},\n  \"num_parts\": {num_parts},\n  \"model\": {{\"layers\": {}, \"feat_dim\": {}, \"hidden\": {}, \"classes\": {}}},\n  \"graph\": {{\"nodes\": {graph_nodes}, \"edges\": {graph_edges}}},\n  \"total_bytes\": {},\n  \"shards\": [\n    {shards}\n  ]\n}}\n",
        model.layers,
        model.feat_dim,
        model.hidden,
        model.classes,
        stats.total_bytes
    )
}

/// Durably commit a rendered manifest (tmp → fsync → rename → dir fsync),
/// always the **last** write of a store.
pub(crate) fn commit_manifest(dir: &Path, json: &str) -> Result<()> {
    let path = dir.join("manifest.json");
    let tmp = binio::tmp_sibling(&path);
    let guard = binio::TmpGuard::new(tmp.clone());
    let f = std::fs::File::create(&tmp).with_context(|| format!("create {tmp:?}"))?;
    let mut w = BufWriter::new(f);
    w.write_all(json.as_bytes())?;
    w.flush()?;
    w.get_ref().sync_all().with_context(|| format!("fsyncing {tmp:?}"))?;
    binio::commit_replace(&tmp, &path)?;
    guard.disarm();
    Ok(())
}

// ---------------------------------------------------------------------------
// Manifest reading and per-file checking (the fsck primitives).
// ---------------------------------------------------------------------------

/// One `manifest.json` shard row, as read back from disk.
#[derive(Clone, Debug)]
pub struct ManifestEntry {
    pub file: String,
    pub part_id: u64,
    pub bytes: u64,
    /// Absent in stores written before format v2.
    pub crc32c: Option<u32>,
    /// Replicated node count of the partition (absent in hand-edited or
    /// foreign manifests; every store this repo writes records it).
    pub nodes: Option<u64>,
    /// Canonical edge count of the partition.
    pub edges: Option<u64>,
}

/// The parts of `manifest.json` that integrity tooling consumes.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub format: String,
    pub num_parts: u64,
    pub total_bytes: u64,
    /// Full-graph sizes from the `graph` object (absent only in foreign or
    /// truncated manifests) — what manifest-only partition metrics divide
    /// by.
    pub graph_nodes: Option<u64>,
    pub graph_edges: Option<u64>,
    pub shards: Vec<ManifestEntry>,
}

/// Read and validate `dir/manifest.json`. A missing manifest is an
/// error by design: the manifest is written last, so its absence means
/// the store never completed.
pub fn read_manifest(dir: &Path) -> Result<Manifest> {
    let path = dir.join("manifest.json");
    let bytes = match std::fs::read(&path) {
        Ok(b) => b,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
            bail!(
                "no manifest.json in {dir:?} — a shard dir without a manifest is \
                 incomplete (`cofree shard` writes it last, after every shard file)"
            );
        }
        Err(e) => return Err(e).with_context(|| format!("reading {path:?}")),
    };
    let doc = json::parse(&bytes).with_context(|| format!("parsing {path:?}"))?;
    let format = doc
        .get("format")
        .and_then(Json::as_str)
        .context("manifest missing string field `format`")?
        .to_string();
    let format_version: u64 = format
        .strip_prefix("cofree-shards-v")
        .and_then(|v| v.parse().ok())
        .with_context(|| format!("manifest `format` is {format:?}, expected cofree-shards-v<N>"))?;
    let num_parts =
        doc.get("num_parts").and_then(Json::as_u64).context("manifest missing `num_parts`")?;
    let total_bytes =
        doc.get("total_bytes").and_then(Json::as_u64).context("manifest missing `total_bytes`")?;
    let graph_nodes = doc.get("graph").and_then(|g| g.get("nodes")).and_then(Json::as_u64);
    let graph_edges = doc.get("graph").and_then(|g| g.get("edges")).and_then(Json::as_u64);
    let rows = doc.get("shards").and_then(Json::as_arr).context("manifest missing `shards`")?;
    ensure!(
        rows.len() as u64 == num_parts,
        "manifest lists {} shards but num_parts is {num_parts}",
        rows.len()
    );
    let mut shards = Vec::with_capacity(rows.len());
    for (i, row) in rows.iter().enumerate() {
        let file = row
            .get("file")
            .and_then(Json::as_str)
            .with_context(|| format!("manifest shard {i} missing `file`"))?
            .to_string();
        ensure!(
            !file.is_empty() && !file.contains('/') && !file.contains('\\'),
            "manifest shard {i} file name {file:?} must be a bare name"
        );
        let part_id = row
            .get("part_id")
            .and_then(Json::as_u64)
            .with_context(|| format!("manifest shard {i} missing `part_id`"))?;
        let bytes = row
            .get("bytes")
            .and_then(Json::as_u64)
            .with_context(|| format!("manifest shard {i} missing `bytes`"))?;
        let crc32c = match row.get("crc32c") {
            None => {
                // The digest column is the point of format v2: its absence
                // in a v2+ manifest means the row was tampered with or the
                // writer was cut off, not a legacy store.
                ensure!(
                    format_version < 2,
                    "manifest shard {i} is missing `crc32c`, required since format v2 \
                     (store says {format:?})"
                );
                None
            }
            Some(v) => {
                let n = v
                    .as_u64()
                    .with_context(|| format!("manifest shard {i} `crc32c` is not an integer"))?;
                ensure!(n <= u32::MAX as u64, "manifest shard {i} `crc32c` {n} exceeds u32");
                Some(n as u32)
            }
        };
        let nodes = row.get("nodes").and_then(Json::as_u64);
        let edges = row.get("edges").and_then(Json::as_u64);
        shards.push(ManifestEntry { file, part_id, bytes, crc32c, nodes, edges });
    }
    Ok(Manifest { format, num_parts, total_bytes, graph_nodes, graph_edges, shards })
}

/// Verdict of a full structural + digest check of one shard file.
#[derive(Clone, Debug)]
pub struct ShardCheck {
    pub version: u32,
    pub bytes: u64,
    pub part_id: usize,
    pub num_parts: usize,
    pub n_local: usize,
    /// Full-file CRC-32C of the raw bytes on disk (what the manifest
    /// records) — computed whether or not the file stores digests.
    pub full_file_crc32c: u32,
    pub integrity: Integrity,
    /// Per-section digests verified (0 for legacy v1 files).
    pub sections_checked: usize,
}

/// Fully check one shard file: structure, lengths, edge canonicality,
/// the whole-file digest, and every per-section digest (so a corruption
/// is attributed to the array it landed in). This is the per-file
/// workhorse behind `cofree fsck`.
pub fn check_shard_file(path: &Path) -> Result<ShardCheck> {
    let map = Mmap::open(path)?;
    let bytes = map.bytes();
    let parsed = parse_shard_bytes(bytes, path)?;
    let sections_checked = verify_shard_digests(bytes, &parsed, true)?;
    decode_checked_edges(&bytes[parsed.edges.0..parsed.edges.1], parsed.n_local)?;
    let integrity =
        if parsed.digests.is_some() { Integrity::Verified } else { Integrity::LegacyUnverified };
    Ok(ShardCheck {
        version: parsed.version,
        bytes: bytes.len() as u64,
        part_id: parsed.part_id,
        num_parts: parsed.num_parts,
        n_local: parsed.n_local,
        full_file_crc32c: crc32c(bytes),
        integrity,
        sections_checked,
    })
}

/// List the shard files in `dir`, sorted by part id (file-name order).
/// Errors if the directory holds no shards.
pub fn shard_files(dir: &Path) -> Result<Vec<PathBuf>> {
    let mut out: Vec<PathBuf> = std::fs::read_dir(dir)
        .with_context(|| format!("read shard dir {dir:?}"))?
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| {
            p.file_name()
                .and_then(|n| n.to_str())
                .map(|n| n.starts_with("shard_") && n.ends_with(".bin"))
                .unwrap_or(false)
        })
        .collect();
    if out.is_empty() {
        bail!("no shard_*.bin files in {dir:?} (run `cofree shard --out {}` first)", dir.display());
    }
    out.sort();
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::features::{synthesize, FeatureParams};
    use crate::partition::testutil::graph_zoo;
    use crate::partition::{algorithm, dar_weights, Reweighting, ALGORITHMS};
    use crate::util::rng::Rng;

    fn tmp_dir(name: &str) -> PathBuf {
        let p = std::env::temp_dir().join(format!("cofree_shards_{name}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&p);
        p
    }

    fn dataset_for(g: &Graph, seed: u64) -> Dataset {
        let mut rng = Rng::new(seed);
        let n = g.num_nodes();
        let comm: Vec<u32> = (0..n).map(|i| (i % 4) as u32).collect();
        let nd = synthesize(&comm, 4, &FeatureParams { dim: 6, ..Default::default() }, &mut rng);
        Dataset { name: format!("zoo-{seed}"), graph: g.clone(), data: nd, layers: 2, hidden: 8 }
    }

    /// Adjacency-row snapshot for byte-identity comparisons.
    fn rows(g: &Graph) -> Vec<u32> {
        (0..g.num_nodes() as u32).flat_map(|v| g.neighbors(v).iter().copied().collect::<Vec<_>>()).collect()
    }

    /// Satellite property test: write shards → load → byte-identical
    /// `VertexCut` parts, id tables, DAR weights and node data, across the
    /// graph zoo and every partitioner.
    #[test]
    fn shard_roundtrip_is_byte_identical_across_zoo() {
        let dir = tmp_dir("zoo");
        for (gi, g) in graph_zoo(23).iter().enumerate() {
            let ds = dataset_for(g, 100 + gi as u64);
            for &name in ALGORITHMS.iter() {
                for &p in &[1usize, 3] {
                    let mut rng = Rng::new(7 * gi as u64 + p as u64);
                    let vc = VertexCut::create(g, p, algorithm(name).unwrap().as_ref(), &mut rng);
                    let weights = dar_weights(g, &vc, Reweighting::Dar);
                    let sub = dir.join(format!("{name}_{gi}_{p}"));
                    let stats = write_shards(&ds, &vc, &weights, 9, &sub).unwrap();
                    assert_eq!(stats.files.len(), p);
                    assert!(sub.join("manifest.json").exists());
                    let files = shard_files(&sub).unwrap();
                    assert_eq!(files.len(), p);
                    for (i, file) in files.iter().enumerate() {
                        let sh = Shard::read(file).unwrap();
                        let part = &vc.parts[i];
                        assert_eq!(sh.part_id, i);
                        assert_eq!(sh.num_parts, p);
                        assert_eq!(sh.global_ids, part.global_ids, "{name} g{gi} p{p} shard {i}");
                        assert_eq!(sh.local.edges(), part.local.edges());
                        assert_eq!(rows(&sh.local), rows(&part.local));
                        // DAR weights bit-exact.
                        let a: Vec<u32> = sh.dar.iter().map(|x| x.to_bits()).collect();
                        let b: Vec<u32> = weights[i].iter().map(|x| x.to_bits()).collect();
                        assert_eq!(a, b);
                        // Gathered node data matches the global arrays.
                        for (l, &gid) in part.global_ids.iter().enumerate() {
                            assert_eq!(
                                &sh.data.features[l * 6..(l + 1) * 6],
                                ds.data.feature(gid)
                            );
                            assert_eq!(sh.data.labels[l], ds.data.labels[gid as usize]);
                            assert_eq!(sh.data.split[l], ds.data.split[gid as usize]);
                        }
                    }
                }
            }
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    /// A shard tensorizes to the exact batch the in-process engine builds
    /// for the same partition — the worker-side half of the cross-process
    /// determinism contract.
    #[test]
    fn shard_tensorize_matches_tensorize_partition() {
        use crate::train::tensorize::tensorize_partition;
        let g = &graph_zoo(5)[2];
        let ds = dataset_for(g, 55);
        let mut rng = Rng::new(8);
        let vc = VertexCut::create(g, 4, algorithm("ne").unwrap().as_ref(), &mut rng);
        let weights = dar_weights(g, &vc, Reweighting::Dar);
        let dir = tmp_dir("tensorize");
        write_shards(&ds, &vc, &weights, 3, &dir).unwrap();
        for (i, file) in shard_files(&dir).unwrap().iter().enumerate() {
            let sh = Shard::read(file).unwrap();
            let (n_pad, e_pad) = (256, 1024);
            let a = sh.tensorize(n_pad, e_pad).unwrap();
            let b = tensorize_partition(&vc.parts[i], &ds.data, &weights[i], n_pad, e_pad).unwrap();
            assert_eq!(a.n_used, b.n_used);
            assert_eq!(a.e_used, b.e_used);
            assert_eq!(a.local_train_weight, b.local_train_weight);
            assert_eq!(a.tensors.len(), b.tensors.len());
            for (ti, (x, y)) in a.tensors.iter().zip(&b.tensors).enumerate() {
                assert_eq!(x, y, "tensor {ti} of shard {i}");
            }
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    /// Satellite: the mmap-backed load path is byte-identical to the
    /// streamed read — every array, the rebuilt CSR, and the tensorized
    /// batch — across the zoo and several partitioners.
    #[test]
    fn mmap_load_matches_streamed_read_byte_identically() {
        let dir = tmp_dir("mmapzoo");
        for (gi, g) in graph_zoo(31).iter().enumerate().take(6) {
            let ds = dataset_for(g, 500 + gi as u64);
            for &name in &["dbh", "ne"] {
                let p = 3usize;
                let mut rng = Rng::new(11 * gi as u64 + 1);
                let vc = VertexCut::create(g, p, algorithm(name).unwrap().as_ref(), &mut rng);
                let weights = dar_weights(g, &vc, Reweighting::Dar);
                let sub = dir.join(format!("{name}_{gi}"));
                write_shards(&ds, &vc, &weights, 9, &sub).unwrap();
                for file in shard_files(&sub).unwrap() {
                    let streamed = Shard::read(&file).unwrap();
                    let mapped = MappedShard::open(&file).unwrap();
                    #[cfg(all(unix, target_endian = "little", target_pointer_width = "64"))]
                    assert!(mapped.is_zero_copy(), "expected a real mapping on 64-bit unix/LE");
                    assert_eq!(mapped.part_id, streamed.part_id);
                    assert_eq!(mapped.num_parts, streamed.num_parts);
                    assert_eq!(mapped.model, streamed.model);
                    assert_eq!(mapped.seed, streamed.seed);
                    assert_eq!(mapped.global_ids(), &streamed.global_ids[..]);
                    assert_eq!(mapped.labels(), &streamed.data.labels[..]);
                    assert_eq!(mapped.split(), &streamed.data.split[..]);
                    let b = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
                    assert_eq!(b(mapped.dar()), b(&streamed.dar));
                    assert_eq!(b(mapped.features()), b(&streamed.data.features));
                    assert_eq!(mapped.local.edges(), streamed.local.edges());
                    assert_eq!(rows(&mapped.local), rows(&streamed.local));
                    // Materialized and tensorized forms agree exactly too.
                    let owned = mapped.to_shard();
                    assert_eq!(owned.global_ids, streamed.global_ids);
                    let (n_pad, e_pad) = (256, 2048);
                    let ta = mapped.tensorize(n_pad, e_pad).unwrap();
                    let tb = streamed.tensorize(n_pad, e_pad).unwrap();
                    assert_eq!(ta.tensors, tb.tensors);
                    assert_eq!(ta.local_train_weight, tb.local_train_weight);
                }
            }
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn mmap_load_rejects_corrupt_files() {
        let dir = tmp_dir("mmapbad");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("shard_0000.bin");
        std::fs::write(&p, b"COFREEG1........").unwrap();
        let err = MappedShard::open(&p).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("COFREESH") && msg.contains("COFREEG1"), "{msg}");
        // Truncated mid-array: write a valid shard then chop it.
        let g = &graph_zoo(5)[2];
        let ds = dataset_for(g, 77);
        let mut rng = Rng::new(3);
        let vc = VertexCut::create(g, 2, algorithm("dbh").unwrap().as_ref(), &mut rng);
        let weights = dar_weights(g, &vc, Reweighting::Dar);
        let sub = dir.join("ok");
        write_shards(&ds, &vc, &weights, 1, &sub).unwrap();
        let file = &shard_files(&sub).unwrap()[0];
        let bytes = std::fs::read(file).unwrap();
        let cut = dir.join("shard_cut.bin");
        std::fs::write(&cut, &bytes[..bytes.len() - 7]).unwrap();
        assert!(MappedShard::open(&cut).is_err(), "truncated shard must not load");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn shard_rejects_wrong_magic_with_found_vs_expected() {
        let dir = tmp_dir("badmagic");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("shard_0000.bin");
        std::fs::write(&p, b"COFREEG1........").unwrap();
        let err = Shard::read(&p).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("COFREESH") && msg.contains("COFREEG1"), "{msg}");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn shard_files_requires_shards() {
        let dir = tmp_dir("empty");
        std::fs::create_dir_all(&dir).unwrap();
        assert!(shard_files(&dir).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    /// Write one small sharded store and return its dir.
    fn small_store(name: &str, p: usize) -> (PathBuf, ShardSetStats) {
        let g = &graph_zoo(5)[2];
        let ds = dataset_for(g, 91);
        let mut rng = Rng::new(13);
        let vc = VertexCut::create(g, p, algorithm("dbh").unwrap().as_ref(), &mut rng);
        let weights = dar_weights(g, &vc, Reweighting::Dar);
        let dir = tmp_dir(name);
        let stats = write_shards(&ds, &vc, &weights, 5, &dir).unwrap();
        (dir, stats)
    }

    /// Re-emit a shard in the legacy v1 layout (no digest block) — the
    /// compatibility fixture for legacy-load tests.
    fn write_v1(shard: &Shard, path: &Path) {
        let flat: Vec<u32> = shard.local.edges().iter().flat_map(|&(u, v)| [u, v]).collect();
        let f = std::fs::File::create(path).unwrap();
        let mut w = BufWriter::new(f);
        binio::write_magic(&mut w, SHARD_MAGIC).unwrap();
        binio::write_version(&mut w, 1).unwrap();
        shard.emit_scalars(&mut w).unwrap();
        binio::write_u32s(&mut w, &shard.global_ids).unwrap();
        binio::write_u32s(&mut w, &flat).unwrap();
        binio::write_f32s(&mut w, &shard.dar).unwrap();
        binio::write_f32s(&mut w, &shard.data.features).unwrap();
        binio::write_u32s(&mut w, &shard.data.labels).unwrap();
        binio::write_bytes(&mut w, &shard.data.split).unwrap();
        w.flush().unwrap();
    }

    /// Tentpole: a v2 store is fully self-verifying — loads report
    /// `verified`, the manifest's CRC matches the raw bytes on disk, and
    /// `check_shard_file` validates every section digest.
    #[test]
    fn v2_store_verifies_and_manifest_records_crc() {
        let (dir, stats) = small_store("v2verify", 3);
        let man = read_manifest(&dir).unwrap();
        assert_eq!(man.format, format!("cofree-shards-v{SHARD_VERSION}"));
        assert_eq!(man.num_parts, 3);
        assert_eq!(man.shards.len(), stats.files.len());
        for (rec, entry) in stats.files.iter().zip(&man.shards) {
            assert_eq!(entry.file, rec.name);
            assert_eq!(entry.bytes, rec.bytes);
            assert_eq!(entry.crc32c, Some(rec.crc32c));
            let raw = std::fs::read(dir.join(&entry.file)).unwrap();
            assert_eq!(raw.len() as u64, entry.bytes, "manifest byte length is live");
            assert_eq!(crc32c(&raw), rec.crc32c, "manifest CRC matches raw disk bytes");
        }
        for file in shard_files(&dir).unwrap() {
            let (_, integ) = Shard::read_with(&file, Verify::Full).unwrap();
            assert_eq!(integ, Integrity::Verified);
            let (_, integ) = Shard::read_with(&file, Verify::Skip).unwrap();
            assert_eq!(integ, Integrity::SkippedByRequest);
            assert_eq!(MappedShard::open(&file).unwrap().integrity(), Integrity::Verified);
            assert_eq!(
                MappedShard::open_with(&file, Verify::Skip).unwrap().integrity(),
                Integrity::SkippedByRequest
            );
            let check = check_shard_file(&file).unwrap();
            assert_eq!(check.version, SHARD_VERSION);
            assert_eq!(check.integrity, Integrity::Verified);
            assert_eq!(check.sections_checked, SHARD_SECTIONS.len());
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    /// Satellite: legacy v1 files (no digest block) still load through
    /// every path, flagged `legacy-unverified`, with identical contents.
    #[test]
    fn legacy_v1_shard_loads_flagged_unverified() {
        let (dir, _) = small_store("legacyv1", 2);
        let file = &shard_files(&dir).unwrap()[0];
        let modern = Shard::read(file).unwrap();
        let old = dir.join("legacy_0000.bin");
        write_v1(&modern, &old);
        let (loaded, integ) = Shard::read_with(&old, Verify::Full).unwrap();
        assert_eq!(integ, Integrity::LegacyUnverified);
        assert_eq!(loaded.global_ids, modern.global_ids);
        assert_eq!(loaded.local.edges(), modern.local.edges());
        assert_eq!(loaded.data.split, modern.data.split);
        let mapped = MappedShard::open(&old).unwrap();
        assert_eq!(mapped.integrity(), Integrity::LegacyUnverified);
        assert_eq!(mapped.global_ids(), &modern.global_ids[..]);
        let check = check_shard_file(&old).unwrap();
        assert_eq!(check.version, 1);
        assert_eq!(check.integrity, Integrity::LegacyUnverified);
        assert_eq!(check.sections_checked, 0);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    /// Tentpole: a flipped payload byte is caught by every verifying load
    /// path with a digest-mismatch error, while `--no-verify` (by design)
    /// trusts the bytes when the damage is structurally invisible.
    #[test]
    fn digest_verification_catches_flipped_payload_bytes() {
        let (dir, _) = small_store("flippayload", 2);
        let file = &shard_files(&dir).unwrap()[0];
        let mut bytes = std::fs::read(file).unwrap();
        // Last byte = final split mask: structurally valid either way.
        *bytes.last_mut().unwrap() ^= 0x40;
        let bad = dir.join("shard_bad.bin");
        std::fs::write(&bad, &bytes).unwrap();
        for err in [
            format!("{:#}", Shard::read(&bad).unwrap_err()),
            format!("{:#}", MappedShard::open(&bad).unwrap_err()),
            format!("{:#}", check_shard_file(&bad).unwrap_err()),
        ] {
            assert!(err.contains("digest mismatch"), "wanted a digest error, got: {err}");
        }
        // Skip really skips: the corrupt byte is invisible without digests.
        assert!(Shard::read_with(&bad, Verify::Skip).is_ok());
        assert!(MappedShard::open_with(&bad, Verify::Skip).is_ok());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    /// check_shard_file names the section a corruption landed in.
    #[test]
    fn fsck_check_localizes_corruption_to_a_section() {
        let (dir, _) = small_store("fsckname", 2);
        let file = &shard_files(&dir).unwrap()[0];
        let mut bytes = std::fs::read(file).unwrap();
        let last = bytes.len() - 1;
        bytes[last] ^= 0x01; // final split-mask byte
        std::fs::write(file, &bytes).unwrap();
        // Whole-file digest fires first…
        let err = format!("{:#}", check_shard_file(file).unwrap_err());
        assert!(err.contains("digest mismatch"), "{err}");
        // …and with the file digest patched to match, the per-section
        // check still pins the flip to the split section.
        let map = Mmap::open(file).unwrap();
        let parsed = parse_shard_bytes(map.bytes(), file).unwrap();
        let body_start = parsed.digests.unwrap().body_start;
        drop(map);
        let fixed = crc32c(&bytes[body_start..]);
        bytes[12..16].copy_from_slice(&fixed.to_le_bytes());
        std::fs::write(file, &bytes).unwrap();
        let err = format!("{:#}", check_shard_file(file).unwrap_err());
        assert!(err.contains("section `split`"), "{err}");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    /// Satellite: the manifest is the completion marker — its absence
    /// means "incomplete store", and a garbled one is a structured error.
    #[test]
    fn missing_manifest_means_incomplete_store() {
        let (dir, _) = small_store("nomanifest", 2);
        std::fs::remove_file(dir.join("manifest.json")).unwrap();
        let err = format!("{:#}", read_manifest(&dir).unwrap_err());
        assert!(err.contains("incomplete"), "{err}");
        std::fs::write(dir.join("manifest.json"), b"{\"format\": \"cofree-shards-v2\",").unwrap();
        assert!(read_manifest(&dir).is_err(), "garbled manifest must not parse");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    /// The digest column is mandatory for v2+ manifests (a v2 row
    /// without one is tampering or a torn write, not a legacy store),
    /// the format version must be numeric, and v1 rows may legitimately
    /// omit the CRC.
    #[test]
    fn manifest_crc_is_required_since_v2() {
        let (dir, _) = small_store("crcrequired", 1);
        let row = |crc: &str| {
            format!(
                "{{\n  \"format\": \"cofree-shards-v2\",\n  \"num_parts\": 1,\n  \
                 \"total_bytes\": 10,\n  \"shards\": [\n    \
                 {{\"file\": \"shard_0000.bin\", \"part_id\": 0, \"bytes\": 10{crc}}}\n  ]\n}}\n"
            )
        };
        std::fs::write(dir.join("manifest.json"), row("")).unwrap();
        let err = format!("{:#}", read_manifest(&dir).unwrap_err());
        assert!(err.contains("crc32c") && err.contains("required since"), "{err}");
        std::fs::write(dir.join("manifest.json"), row(", \"crc32c\": 7")).unwrap();
        assert_eq!(read_manifest(&dir).unwrap().shards[0].crc32c, Some(7));
        // v1 stores predate the digest column: the row parses CRC-less.
        let v1 = row("").replace("cofree-shards-v2", "cofree-shards-v1");
        std::fs::write(dir.join("manifest.json"), v1).unwrap();
        assert_eq!(read_manifest(&dir).unwrap().shards[0].crc32c, None);
        // A garbled version suffix is a structured error, not a guess.
        let vx = row(", \"crc32c\": 7").replace("cofree-shards-v2", "cofree-shards-vX");
        std::fs::write(dir.join("manifest.json"), vx).unwrap();
        let err = format!("{:#}", read_manifest(&dir).unwrap_err());
        assert!(err.contains("cofree-shards-v<N>"), "{err}");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    /// No `.tmp` residue survives a successful store write.
    #[test]
    fn store_write_leaves_no_temporaries() {
        let (dir, _) = small_store("notmp", 3);
        for entry in std::fs::read_dir(&dir).unwrap() {
            let name = entry.unwrap().file_name().into_string().unwrap();
            assert!(!name.ends_with(".tmp"), "stray temporary {name}");
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
