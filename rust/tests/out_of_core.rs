//! Out-of-core ingest properties (crate-public API, zoo-wide).
//!
//! The streamed pipeline (`ingest::stream_shards`) must write **bitwise
//! identical** stores to the in-memory pipeline (`write_shards` over a
//! `VertexCut`) — shard bytes and manifest bytes — for every graph shape,
//! chunk size (down to one edge) and rayon thread count, and the result
//! must pass fsck even when a tiny budget forces real spills and
//! multi-pass merges.

use cofree_gnn::dist;
use cofree_gnn::graph::{generators, io, Dataset, GraphBuilder};
use cofree_gnn::ingest::{self, SliceSource, StreamAlgo, StreamDataset, StreamOptions};
use cofree_gnn::partition::{algorithm, dar_weights, Reweighting, VertexCut};
use cofree_gnn::util::rng::Rng;
use std::path::{Path, PathBuf};

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("cofree_ooc_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

/// In-memory oracle store for `pairs` with the same synthesized node data
/// the streamed path uses.
fn write_oracle(pairs: &[(u32, u32)], n: usize, seed: u64, algo: &str, p: usize, dir: &Path) {
    let ds = Dataset {
        name: "ooc-zoo".into(),
        graph: GraphBuilder::new(n).edges(pairs).build(),
        data: ingest::synth_node_data(n, seed),
        layers: ingest::SYNTH_LAYERS,
        hidden: ingest::SYNTH_HIDDEN,
    };
    let a = algorithm(algo).unwrap();
    let vc = VertexCut::create(&ds.graph, p, a.as_ref(), &mut Rng::new(seed));
    let weights = dar_weights(&ds.graph, &vc, Reweighting::Dar);
    dist::write_shards(&ds, &vc, &weights, seed, dir).unwrap();
}

#[allow(clippy::too_many_arguments)]
fn stream_store(
    pairs: &[(u32, u32)],
    n: usize,
    seed: u64,
    algo: StreamAlgo,
    p: usize,
    chunk: usize,
    dir: &Path,
) -> ingest::StreamStats {
    let data = ingest::synth_node_data(n, seed);
    let sds = StreamDataset {
        name: "ooc-zoo",
        data: &data,
        layers: ingest::SYNTH_LAYERS,
        hidden: ingest::SYNTH_HIDDEN,
    };
    let mut opts = StreamOptions::new(p, algo, Reweighting::Dar, seed);
    opts.chunk_edges = Some(chunk);
    opts.fan_in = 4;
    let mut src = SliceSource::new(n, pairs);
    ingest::stream_shards(&mut src, &sds, &opts, dir).unwrap()
}

/// Every file in `a` exists in `b` with identical bytes, and vice versa.
fn assert_same_store(a: &Path, b: &Path) {
    let list = |d: &Path| -> Vec<String> {
        let mut names: Vec<String> = std::fs::read_dir(d)
            .unwrap()
            .map(|e| e.unwrap().file_name().into_string().unwrap())
            .collect();
        names.sort();
        names
    };
    let names = list(a);
    assert!(names.iter().any(|n| n == "manifest.json"), "{a:?} has no manifest");
    assert_eq!(names, list(b), "store listings differ ({a:?} vs {b:?})");
    for name in &names {
        let x = std::fs::read(a.join(name)).unwrap();
        let y = std::fs::read(b.join(name)).unwrap();
        assert_eq!(x, y, "{name} differs between {a:?} and {b:?}");
    }
}

/// Raw pair streams covering the shapes that stress the pipeline:
/// duplicates and self-loops, heavy-tailed hubs, power-law degrees, a
/// star, a path echoed in both orientations, and a loops-only stream that
/// canonicalizes to an edgeless graph.
fn zoo(seed: u64) -> Vec<(String, usize, Vec<(u32, u32)>)> {
    let mut rng = Rng::new(seed);
    let mut out = Vec::new();
    let n = 150usize;
    let mut pairs = Vec::new();
    for _ in 0..900 {
        pairs.push((rng.below(n) as u32, rng.below(n) as u32));
    }
    out.push(("uniform-messy".to_string(), n, pairs));
    let pairs = generators::rmat_pairs(7, 700, generators::RmatParams::default(), &mut rng);
    out.push(("rmat".to_string(), 128, pairs));
    let w = generators::power_law_degrees(300, 2.3, 2, 40, &mut rng);
    let pairs = generators::chung_lu_pairs(&w, &mut rng);
    out.push(("chung-lu".to_string(), 300, pairs));
    let pairs: Vec<(u32, u32)> = (1..64u32).map(|v| (0, v)).collect();
    out.push(("star".to_string(), 64, pairs));
    let mut pairs: Vec<(u32, u32)> = (0..99u32).map(|v| (v, v + 1)).collect();
    pairs.extend((0..99u32).map(|v| (v + 1, v)));
    out.push(("path-dup".to_string(), 100, pairs));
    out.push(("loops-only".to_string(), 10, vec![(3, 3), (7, 7)]));
    out
}

/// Zoo-wide parity: streamed stores equal in-memory stores byte-for-byte
/// for every graph shape and chunk size, including one-edge chunks.
#[test]
fn zoo_parity_across_chunk_sizes() {
    for (name, n, pairs) in zoo(0xC0FFEE) {
        let oracle = tmpdir(&format!("oracle_{name}"));
        write_oracle(&pairs, n, 11, "dbh", 3, &oracle);
        for chunk in [1usize, 29, 1 << 20] {
            let dir = tmpdir(&format!("stream_{name}_{chunk}"));
            let stats = stream_store(&pairs, n, 11, StreamAlgo::Dbh, 3, chunk, &dir);
            assert_eq!(stats.raw_pairs, pairs.len() as u64, "{name}");
            assert!(!dir.join(ingest::SCRATCH_DIR_NAME).exists(), "{name}: scratch left");
            assert_same_store(&oracle, &dir);
            std::fs::remove_dir_all(&dir).unwrap();
        }
        std::fs::remove_dir_all(&oracle).unwrap();
    }
}

/// The spill sorter sorts chunks on rayon's current pool; the stores must
/// not depend on parallelism. Same ingest under 1- and 4-thread pools.
#[test]
fn parity_across_thread_counts() {
    let mut rng = Rng::new(5);
    let pairs = generators::rmat_pairs(7, 900, generators::RmatParams::default(), &mut rng);
    let oracle = tmpdir("threads_oracle");
    write_oracle(&pairs, 128, 23, "greedy-seq", 4, &oracle);
    for threads in [1usize, 4] {
        let dir = tmpdir(&format!("threads_{threads}"));
        let pool = rayon::ThreadPoolBuilder::new().num_threads(threads).build().unwrap();
        pool.install(|| {
            stream_store(&pairs, 128, 23, StreamAlgo::GreedySeq, 4, 37, &dir);
        });
        assert_same_store(&oracle, &dir);
        std::fs::remove_dir_all(&dir).unwrap();
    }
    std::fs::remove_dir_all(&oracle).unwrap();
}

/// A chunk size far below the edge count forces real spills and
/// multi-pass merging, and the resulting store still passes fsck.
#[test]
fn tiny_budget_spills_merges_and_passes_fsck() {
    let mut rng = Rng::new(9);
    let pairs = generators::rmat_pairs(8, 4000, generators::RmatParams::default(), &mut rng);
    let dir = tmpdir("budget");
    let stats = stream_store(&pairs, 256, 31, StreamAlgo::Dbh, 4, 100, &dir);
    assert!(stats.runs_spilled >= 30, "runs_spilled={}", stats.runs_spilled);
    assert!(stats.merge_passes >= 2, "merge_passes={}", stats.merge_passes);
    assert!(stats.spill_bytes > 0);
    assert!(!dir.join(ingest::SCRATCH_DIR_NAME).exists());
    let report = dist::fsck(&dir).unwrap();
    assert!(report.ok(), "{report}");
    std::fs::remove_dir_all(&dir).unwrap();
}

/// `--input edges.bin` semantics at the library level: streaming straight
/// off the binary edge-list file equals the in-memory store built from
/// the same pairs.
#[test]
fn edge_list_file_source_matches_in_memory() {
    let mut rng = Rng::new(13);
    let n = 200usize;
    let mut pairs = Vec::new();
    for _ in 0..1200 {
        pairs.push((rng.below(n) as u32, rng.below(n) as u32));
    }
    let dir = tmpdir("binsrc");
    std::fs::create_dir_all(&dir).unwrap();
    let file = dir.join("edges.bin");
    io::write_edge_list_bin(n, &pairs, &file).unwrap();
    let oracle = dir.join("oracle");
    write_oracle(&pairs, n, 3, "random", 2, &oracle);
    let streamed = dir.join("streamed");
    let data = ingest::synth_node_data(n, 3);
    let sds = StreamDataset {
        name: "ooc-zoo",
        data: &data,
        layers: ingest::SYNTH_LAYERS,
        hidden: ingest::SYNTH_HIDDEN,
    };
    let mut opts = StreamOptions::new(2, StreamAlgo::Random, Reweighting::Dar, 3);
    opts.chunk_edges = Some(171);
    let mut src = io::EdgeListBinReader::open(&file).unwrap();
    ingest::stream_shards(&mut src, &sds, &opts, &streamed).unwrap();
    assert_same_store(&oracle, &streamed);
    std::fs::remove_dir_all(&dir).unwrap();
}
