//! The `cofree worker` role: one process, one shard, zero graph knowledge
//! beyond its own partition.
//!
//! A worker **memory-maps** its shard ([`MappedShard`] — header validated
//! in place, feature/label/weight arrays borrowed straight from the page
//! cache, no deserialization copy), connects to the coordinator, prepares
//! its partition exactly the way the in-process engine would — same padded
//! bucket ([`pad_explicit`]), same tensorization, same DropEdge-K mask
//! bank drawn from the same forked RNG stream ([`worker_mask_rng`], the
//! single definition `prepare_partitions` also uses) — and then answers
//! `Step` frames with `StepResult`s until the coordinator says `Shutdown`.
//!
//! The worker trains whatever architecture the coordinator's `Config`
//! frame names ([`ModelKind`](crate::train::model::ModelKind) travels on
//! the wire; the shard stores only dims, which must match).
//!
//! Two connection modes:
//!
//! * [`run`] — dial out to a coordinator (`--connect`): the local-fleet
//!   shape, where the coordinator spawned this process and respawns it on
//!   failure.
//! * [`run_listen`] — bind a port and *accept* coordinator sessions
//!   (`--listen`): the multi-host shape (`cofree train --hosts …`), where
//!   the coordinator did not spawn the worker and recovery means the
//!   coordinator re-dialing. Each accepted connection is one full session
//!   (Hello → Config → Meta → steps); a dropped session returns the
//!   worker to `accept`, so a recovering coordinator finds it ready.
//!
//! Workers are **stateless between steps** — parameters arrive with every
//! `Step`, the mask bank re-derives from `(seed, rank)` — which is what
//! makes crash recovery bit-exact: a respawned worker that replays the
//! same handshake produces the same `Meta` and the same `TrainOut`s as
//! its predecessor would have.
//!
//! The step loop is allocation-free in steady state: incoming frames land
//! in one reusable [`proto::FrameBuf`], parameters decode into one reused
//! `ParamSet`, the train step runs through the worker's persistent
//! [`ModelWorkspace`] arena into one reused `TrainOut`, and the result
//! frame serializes through one reused payload buffer. Because every
//! input bit and every RNG draw matches the in-process path, the
//! `TrainOut` it returns is bit-identical to what the same partition
//! would have produced inside the coordinator's address space.
//!
//! When `COFREE_CHAOS` is set the stream is wrapped in the
//! [`fault::FaultStream`] shim, which injects kill/hang/delay/exit faults
//! at exact frame boundaries — the chaos harness (`tests/chaos.rs`).

use super::fault::{FaultPlan, FaultStream};
use super::proto::{self, Frame, Stream, WireCodec, PROTO_VERSION};
use super::shard::MappedShard;
use crate::runtime::{ParamSet, TrainOut};
use crate::train::bucket::pad_explicit;
use crate::train::cpu::{self, EdgeCsr};
use crate::train::dropedge::MaskBank;
use crate::train::engine::worker_mask_rng;
use crate::train::model::Precision;
use crate::train::workspace::ModelWorkspace;
use crate::util::binio::Verify;
use anyhow::{bail, ensure, Context, Result};
use std::io::{Read, Write};
use std::net::TcpListener;
use std::path::Path;
use std::time::Instant;

/// Worker-side negotiation constraints, from `cofree worker`'s
/// `--wire-compress` / `--precision` flags. Defaults advertise every codec
/// and adopt whatever compute tier the coordinator's `Config` names.
#[derive(Clone, Copy, Debug)]
pub struct WorkerOptions {
    /// Codec bitmask advertised in the Hello (protocol v6). `--wire-compress
    /// CODEC` narrows this to f32 + CODEC; a coordinator whose negotiated
    /// codec is missing from the mask refuses the fleet loudly by rank.
    pub codecs: u8,
    /// When set, refuse a `Config` naming a different compute tier — a
    /// deployment guard for hosts that must not silently train at an
    /// unexpected precision.
    pub precision: Option<Precision>,
}

impl Default for WorkerOptions {
    fn default() -> WorkerOptions {
        WorkerOptions { codecs: WireCodec::all_bits(), precision: None }
    }
}

/// Dial out to a coordinator and serve one session to completion.
/// Returns the number of train steps served.
///
/// The connection is established *before* the shard is opened: a shard
/// that fails its integrity checks is reported to the coordinator as a
/// structured [`Frame::Fault`] (corrupt vs transient) instead of the
/// worker dying silently mid-handshake.
pub fn run(shard_path: &Path, connect: &str, verify: Verify) -> Result<usize> {
    run_with(shard_path, connect, verify, WorkerOptions::default())
}

/// [`run`] with explicit negotiation constraints ([`WorkerOptions`]).
pub fn run_with(
    shard_path: &Path,
    connect: &str,
    verify: Verify,
    opts: WorkerOptions,
) -> Result<usize> {
    crate::log_info!("worker: connecting to {connect} for shard {}", shard_path.display());
    let mut stream = Stream::connect(connect)?;
    let shard = match open_shard(shard_path, verify) {
        Ok(s) => s,
        Err(e) => return report_fault(&mut stream, shard_path, e),
    };
    serve(&shard, stream, opts)
}

/// Bind `listen` (host:port) and serve coordinator sessions until one ends
/// in a clean `Shutdown`. A dropped session (coordinator crash, network
/// loss, coordinator-driven recovery re-dialing) is logged and the worker
/// returns to `accept`. Returns total train steps served across sessions.
pub fn run_listen(shard_path: &Path, listen: &str, verify: Verify) -> Result<usize> {
    run_listen_with(shard_path, listen, verify, WorkerOptions::default())
}

/// [`run_listen`] with explicit negotiation constraints ([`WorkerOptions`]).
pub fn run_listen_with(
    shard_path: &Path,
    listen: &str,
    verify: Verify,
    opts: WorkerOptions,
) -> Result<usize> {
    let shard = match open_shard(shard_path, verify) {
        Ok(s) => s,
        Err(e) => {
            // The shard is unusable, but a coordinator may already be
            // dialing this endpoint: accept one session, report the fault
            // in-band so the operator sees *which* file is bad, then exit
            // nonzero.
            let listener = TcpListener::bind(listen)
                .with_context(|| format!("worker: binding {listen} to report a fault"))?;
            crate::log_error!(
                "worker: shard {} unusable ({e:#}); reporting to the next coordinator",
                shard_path.display()
            );
            let (sock, _peer) = listener.accept().context("accepting coordinator session")?;
            let mut stream = Stream::from_tcp(sock)?;
            return report_fault(&mut stream, shard_path, e);
        }
    };
    let listener = TcpListener::bind(listen)
        .with_context(|| format!("worker rank {}: binding {listen}", shard.part_id))?;
    let addr = listener.local_addr()?;
    crate::log_info!(
        "worker rank {}/{}: listening on {addr} for a coordinator",
        shard.part_id,
        shard.num_parts
    );
    let mut total = 0usize;
    loop {
        let (sock, peer) = listener.accept().context("accepting coordinator session")?;
        crate::log_info!("worker rank {}: session from {peer}", shard.part_id);
        let stream = Stream::from_tcp(sock)?;
        match serve(&shard, stream, opts) {
            Ok(steps) => return Ok(total + steps),
            Err(e) => {
                crate::log_warn!(
                    "worker rank {}: session from {peer} ended ({e:#}); awaiting reconnect",
                    shard.part_id
                );
            }
        }
    }
}

fn open_shard(shard_path: &Path, verify: Verify) -> Result<MappedShard> {
    let shard = MappedShard::open_with(shard_path, verify)
        .with_context(|| format!("loading shard {}", shard_path.display()))?;
    crate::log_info!(
        "worker rank {}/{}: shard {} (n_local={}, m_local={}, zero_copy={}, {})",
        shard.part_id,
        shard.num_parts,
        shard_path.display(),
        shard.n_local(),
        shard.local.num_edges(),
        shard.is_zero_copy(),
        shard.integrity()
    );
    Ok(shard)
}

/// Classify a shard-load failure for the coordinator: failures whose cause
/// chain bottoms out in a retryable I/O condition are transient (recycling
/// the worker may succeed); everything else — digest mismatches, bad
/// magic/version, truncation, structural rejects — is corrupt data, where
/// retrying the same bytes cannot help.
fn classify_shard_error(e: &anyhow::Error) -> u8 {
    use std::io::ErrorKind;
    for cause in e.chain() {
        if let Some(ioe) = cause.downcast_ref::<std::io::Error>() {
            return match ioe.kind() {
                ErrorKind::NotFound
                | ErrorKind::PermissionDenied
                | ErrorKind::TimedOut
                | ErrorKind::Interrupted
                | ErrorKind::WouldBlock => proto::FAULT_TRANSIENT,
                _ => proto::FAULT_CORRUPT_DATA,
            };
        }
    }
    proto::FAULT_CORRUPT_DATA
}

/// Send a structured `Fault` frame for a failed shard load, then fail the
/// worker process with the same error. Best-effort on the wire (the
/// coordinator may already be gone); the local log always gets the story.
fn report_fault(stream: &mut Stream, shard_path: &Path, e: anyhow::Error) -> Result<usize> {
    let code = classify_shard_error(&e);
    let detail = format!("shard {}: {e:#}", shard_path.display());
    let kind =
        if code == proto::FAULT_CORRUPT_DATA { "corrupt data" } else { "transient failure" };
    crate::log_error!("worker: reporting {kind} to the coordinator: {detail}");
    if let Err(send_err) =
        proto::write_frame(stream, &Frame::Fault { code, detail: detail.clone() })
    {
        crate::log_warn!("worker: could not deliver the fault report: {send_err:#}");
    }
    Err(e.context("shard unusable (fault reported to coordinator)"))
}

/// Serve one coordinator session over `stream`, wrapping it in the chaos
/// fault shim when a `COFREE_CHAOS` plan targets this rank.
fn serve(shard: &MappedShard, stream: Stream, opts: WorkerOptions) -> Result<usize> {
    match FaultPlan::from_env(shard.part_id) {
        Some(plan) => {
            serve_session(shard, &mut FaultStream::new(stream, plan, shard.part_id), opts)
        }
        None => serve_session(shard, &mut { stream }, opts),
    }
}

/// One full protocol session: Hello → Config → Meta, then the step loop
/// until `Shutdown`. Generic over the stream so the fault shim (and unit
/// tests feeding malformed bytes) slot in transparently.
fn serve_session<S: Read + Write>(
    shard: &MappedShard,
    stream: &mut S,
    opts: WorkerOptions,
) -> Result<usize> {
    let rank = shard.part_id;
    crate::util::logging::set_rank(rank);
    proto::write_frame(
        stream,
        &Frame::Hello {
            proto_version: PROTO_VERSION,
            rank: rank as u32,
            num_parts: shard.num_parts as u32,
            // This build implements every codec; the coordinator picks from
            // whatever subset the operator let this worker advertise.
            codecs: opts.codecs,
        },
    )?;
    let (frame, _) = proto::read_frame(stream)?;
    let Frame::Config {
        seed, dropedge_k, dropedge_ratio, model, wire_digests, precision, wire_codec,
    } = frame
    else {
        bail!("expected Config frame after Hello, got {frame:?}");
    };
    // A correct coordinator never picks a codec outside the advertised
    // mask (check_hello refuses the fleet first); guard anyway so a buggy
    // or hostile peer cannot make this worker emit frames it disclaimed.
    ensure!(
        opts.codecs & wire_codec.bit() != 0,
        "worker rank {rank}: coordinator picked wire codec {} outside the advertised \
         bitmask {:#05b}",
        wire_codec.name(),
        opts.codecs
    );
    if let Some(pin) = opts.precision {
        ensure!(
            pin == precision,
            "worker rank {rank} is pinned to --precision {} but the coordinator's \
             Config names {}; refusing to train at an unexpected tier",
            pin.name(),
            precision.name()
        );
    }
    // Shards record dims only (the stored arrays are model-agnostic); the
    // architecture kind arrives here, in the Config frame, and the worker
    // adopts it. Dims still have to line up with the shard's data layout.
    ensure!(
        model.dims_match(&shard.model),
        "coordinator model dims {model:?} do not match shard dims {:?}",
        shard.model
    );

    // Prepare the partition exactly like TrainEngine::prepare_partitions +
    // CpuBackend::prepare_worker would have. A respawned worker re-derives
    // all of this from the shard + Config alone — same bytes, same RNG
    // stream, same Meta — which is the whole recovery story.
    let (n_pad, e_pad) = pad_explicit(shard.local.num_nodes(), 2 * shard.local.num_edges());
    let batch = shard.tensorize(n_pad, e_pad).context("tensorizing shard")?;
    let csr = EdgeCsr::from_batch(&batch);
    let masks = if dropedge_k > 0 {
        let mut rng = worker_mask_rng(seed, rank);
        MaskBank::generate(&batch, dropedge_k as usize, dropedge_ratio, &mut rng).masks
    } else {
        Vec::new()
    };
    proto::write_frame(
        stream,
        &Frame::Meta {
            local_train_weight: batch.local_train_weight,
            tmask_sum: batch.tmask_sum(),
            num_masks: masks.len() as u32,
        },
    )?;

    // Steady-state arenas: frame buffer, parameter tensors, workspace,
    // output and result payload are all allocated here once and reused
    // for every step.
    let dims = model.param_shapes();
    let mut params = ParamSet { dims: dims.clone(), data: Vec::new() };
    let mut frame_buf = proto::FrameBuf::new();
    // The Config frame carries the fleet's compute tier: the workspace is
    // allocated once at that tier and `train_step_into_timed` dispatches
    // off it, exactly like the in-process engine.
    let mut ws = ModelWorkspace::with_precision(&model, batch.n_pad, precision);
    let mut out = TrainOut::default();
    let mut result_payload: Vec<u8> = Vec::new();
    let mut steps = 0usize;
    // The workspace arena is sized once and never grows — its byte count
    // IS the peak, reported with every step (protocol v5 phase breakdown).
    let peak_workspace_bytes = ws.bytes();
    // Serialize time of the *previous* step's result (encode + write);
    // 0.0 on the first step — the current step's own serialize time is
    // only known after its result frame is already on the wire.
    let mut last_serialize = 0.0f64;
    loop {
        let (tag, payload, _) = proto::read_frame_into(stream, &mut frame_buf)?;
        match tag {
            proto::TAG_STEP => {
                let pick =
                    proto::decode_step_into(payload, &mut params.data, wire_digests, wire_codec)?;
                ensure!(
                    params.data.len() == dims.len(),
                    "expected {} param tensors, got {}",
                    dims.len(),
                    params.data.len()
                );
                for (i, (p, shape)) in params.data.iter().zip(&dims).enumerate() {
                    let want: usize = shape.iter().product();
                    ensure!(
                        p.len() == want,
                        "param tensor {i}: {} elements, expected {want}",
                        p.len()
                    );
                }
                let emask = match pick {
                    Some(k) => {
                        ensure!(k < masks.len(), "mask pick {k} out of range {}", masks.len());
                        masks[k].as_f32()
                    }
                    None => batch.emask().as_f32(),
                };
                let t0 = Instant::now();
                let (forward_seconds, backward_seconds) = cpu::train_step_into_timed(
                    &model, &params, &batch, &csr, emask, &mut ws, &mut out,
                );
                let compute_seconds = t0.elapsed().as_secs_f64();
                let phases = proto::StepPhases {
                    compute_seconds,
                    forward_seconds,
                    backward_seconds,
                    serialize_seconds: last_serialize,
                    peak_workspace_bytes,
                };
                let t1 = Instant::now();
                proto::write_step_result_buffered(
                    stream,
                    &out,
                    &phases,
                    &mut result_payload,
                    wire_digests,
                    wire_codec,
                )?;
                last_serialize = t1.elapsed().as_secs_f64();
                steps += 1;
            }
            proto::TAG_PING => {
                // Liveness probe between epochs: echo the nonce straight
                // back so the coordinator knows this rank is alive.
                let Frame::Ping { nonce } = proto::decode_frame(tag, payload)? else {
                    bail!("Ping tag with non-Ping payload");
                };
                proto::write_frame(stream, &Frame::Pong { nonce })?;
            }
            proto::TAG_SHUTDOWN => {
                ensure!(payload.is_empty(), "Shutdown frame with payload");
                crate::log_info!("worker rank {rank}: shutdown after {steps} steps");
                return Ok(steps);
            }
            other => bail!("unexpected frame tag {other} in step loop"),
        }
    }
}
