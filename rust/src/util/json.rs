//! A minimal, panic-free JSON reader for the shard-store manifest.
//!
//! The repo emits JSON by hand (no serde in the dependency tree, by
//! constraint) and until now nothing parsed any of it back. `cofree fsck`
//! and the shard loader need to *read* `manifest.json` — including
//! manifests that have been bit-flipped or truncated by the corruption
//! chaos suite — so this parser's contract is stricter than usual:
//!
//! * **Never panics, whatever the input.** All indexing is guarded, and
//!   nesting depth is capped ([`MAX_DEPTH`]) so adversarial `[[[[…`
//!   cannot overflow the stack.
//! * **Structured errors with byte offsets**, so fsck can say where a
//!   manifest went bad.
//!
//! It accepts exactly standard JSON (RFC 8259): objects, arrays, strings
//! with escapes, numbers, `true`/`false`/`null`. Numbers are held as
//! `f64`; the integer accessors refuse values that are not exactly
//! representable, which is far beyond any byte count a shard store will
//! ever record.

use anyhow::{bail, Result};

/// Maximum nesting depth before the parser refuses the document.
pub const MAX_DEPTH: usize = 64;

/// Maximum accepted document size (16 MiB): a manifest is a few KiB, so
/// anything bigger is garbage and refused before parsing.
pub const MAX_DOC: usize = 16 << 20;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Insertion-ordered; duplicate keys are kept (last one wins in
    /// [`Json::get`]) rather than being an error.
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Look up `key` in an object (last occurrence wins); `None` for
    /// non-objects or missing keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().rev().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(items) => Some(items),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// The value as an exact non-negative integer, or `None` if it is
    /// not a number, not integral, or too large to hold exactly in f64.
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(x) if x.fract() == 0.0 && *x >= 0.0 && *x <= (1u64 << 53) as f64 => {
                Some(*x as u64)
            }
            _ => None,
        }
    }
}

/// Parse a complete JSON document; trailing non-whitespace is an error.
pub fn parse(bytes: &[u8]) -> Result<Json> {
    if bytes.len() > MAX_DOC {
        bail!("json document too large: {} bytes (cap {MAX_DOC})", bytes.len());
    }
    let mut p = Parser { bytes, pos: 0 };
    p.skip_ws();
    let v = p.value(0)?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        bail!("trailing garbage at byte offset {} of json document", p.pos);
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, want: u8) -> Result<()> {
        match self.peek() {
            Some(b) if b == want => {
                self.pos += 1;
                Ok(())
            }
            Some(b) => bail!(
                "expected `{}` at byte offset {}, found `{}`",
                want as char,
                self.pos,
                if b.is_ascii_graphic() { (b as char).to_string() } else { format!("0x{b:02X}") }
            ),
            None => bail!("expected `{}` at byte offset {}, found end of input", want as char, self.pos),
        }
    }

    /// Consume `word` if it is next (used for true/false/null).
    fn literal(&mut self, word: &str) -> bool {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            true
        } else {
            false
        }
    }

    fn value(&mut self, depth: usize) -> Result<Json> {
        if depth > MAX_DEPTH {
            bail!("json nesting deeper than {MAX_DEPTH} at byte offset {}", self.pos);
        }
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(depth),
            Some(b'[') => self.array(depth),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') | Some(b'f') => {
                if self.literal("true") {
                    Ok(Json::Bool(true))
                } else if self.literal("false") {
                    Ok(Json::Bool(false))
                } else {
                    bail!("malformed literal at byte offset {}", self.pos)
                }
            }
            Some(b'n') => {
                if self.literal("null") {
                    Ok(Json::Null)
                } else {
                    bail!("malformed literal at byte offset {}", self.pos)
                }
            }
            Some(b) if b == b'-' || b.is_ascii_digit() => self.number(),
            Some(b) => bail!(
                "unexpected byte 0x{b:02X} at offset {} where a json value should start",
                self.pos
            ),
            None => bail!("unexpected end of input at byte offset {}", self.pos),
        }
    }

    fn object(&mut self, depth: usize) -> Result<Json> {
        self.expect(b'{')?;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value(depth + 1)?;
            fields.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(fields));
                }
                _ => bail!("expected `,` or `}}` at byte offset {} in object", self.pos),
            }
        }
    }

    fn array(&mut self, depth: usize) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value(depth + 1)?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => bail!("expected `,` or `]` at byte offset {} in array", self.pos),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let start = self.pos;
            // Fast path: run of plain bytes up to the next quote/escape.
            while let Some(b) = self.peek() {
                if b == b'"' || b == b'\\' || b < 0x20 {
                    break;
                }
                self.pos += 1;
            }
            match std::str::from_utf8(&self.bytes[start..self.pos]) {
                Ok(s) => out.push_str(s),
                Err(_) => bail!("invalid utf-8 in string at byte offset {start}"),
            }
            match self.peek() {
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let esc = self.peek();
                    self.pos += 1;
                    match esc {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{0008}'),
                        Some(b'f') => out.push('\u{000C}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            let cp = self.hex4()?;
                            // Surrogate pairs: a high surrogate must be
                            // followed by `\uDC00`-range low surrogate.
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                if !self.literal("\\u") {
                                    bail!("lone high surrogate at byte offset {}", self.pos);
                                }
                                let lo = self.hex4()?;
                                if !(0xDC00..0xE000).contains(&lo) {
                                    bail!("invalid low surrogate at byte offset {}", self.pos);
                                }
                                let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                char::from_u32(c)
                            } else {
                                char::from_u32(cp)
                            };
                            match ch {
                                Some(c) => out.push(c),
                                None => bail!("invalid unicode escape at byte offset {}", self.pos),
                            }
                        }
                        Some(b) => bail!(
                            "unknown escape `\\{}` at byte offset {}",
                            if b.is_ascii_graphic() { b as char } else { '?' },
                            self.pos
                        ),
                        None => bail!("unterminated escape at end of input"),
                    }
                }
                // The fast path stops only at quote/escape/control, so
                // any other `Some` here is a control byte.
                Some(b) => {
                    bail!("raw control byte 0x{b:02X} in string at byte offset {}", self.pos)
                }
                None => bail!("unterminated string at end of input"),
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        let mut v = 0u32;
        for _ in 0..4 {
            let b = match self.peek() {
                Some(b) => b,
                None => bail!("truncated \\u escape at end of input"),
            };
            let d = match b {
                b'0'..=b'9' => (b - b'0') as u32,
                b'a'..=b'f' => (b - b'a') as u32 + 10,
                b'A'..=b'F' => (b - b'A') as u32 + 10,
                _ => bail!("non-hex digit in \\u escape at byte offset {}", self.pos),
            };
            v = (v << 4) | d;
            self.pos += 1;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        let digits_start = self.pos;
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.pos == digits_start {
            bail!("number with no digits at byte offset {start}");
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            let frac_start = self.pos;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
            if self.pos == frac_start {
                bail!("number with empty fraction at byte offset {start}");
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            let exp_start = self.pos;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
            if self.pos == exp_start {
                bail!("number with empty exponent at byte offset {start}");
            }
        }
        // The matched span is pure ASCII by construction.
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap_or("");
        match text.parse::<f64>() {
            Ok(x) if x.is_finite() => Ok(Json::Num(x)),
            _ => bail!("unparseable number `{text}` at byte offset {start}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn parses_the_shapes_the_manifest_uses() {
        let doc = br#"{
  "format": "cofree-shards-v2",
  "seed": 42,
  "num_parts": 3,
  "total_bytes": 123456,
  "ok": true,
  "nothing": null,
  "ratio": 0.25,
  "shards": [
    {"file": "shard_0000.bin", "bytes": 100, "crc32c": 3735928559}
  ]
}"#;
        let v = parse(doc).unwrap();
        assert_eq!(v.get("format").and_then(Json::as_str), Some("cofree-shards-v2"));
        assert_eq!(v.get("seed").and_then(Json::as_u64), Some(42));
        assert_eq!(v.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(v.get("nothing"), Some(&Json::Null));
        assert_eq!(v.get("ratio").and_then(Json::as_f64), Some(0.25));
        let shards = v.get("shards").and_then(Json::as_arr).unwrap();
        assert_eq!(shards.len(), 1);
        assert_eq!(shards[0].get("crc32c").and_then(Json::as_u64), Some(0xDEAD_BEEF));
        assert_eq!(shards[0].get("missing"), None);
    }

    #[test]
    fn strings_with_escapes_round_trip() {
        let v = parse(br#""a\"b\\c\n\t\u0041\u00e9\ud83d\ude00""#).unwrap();
        assert_eq!(v.as_str(), Some("a\"b\\c\n\tA\u{e9}\u{1F600}"));
    }

    #[test]
    fn structured_errors_name_the_offset() {
        for bad in [
            &b"{\"a\": }"[..],
            b"[1, 2",
            b"\"unterminated",
            b"{\"a\" 1}",
            b"tru",
            b"01x",
            b"1e",
            b"-",
            b"[1,]2",
            b"\xFF\xFE",
            b"{\"k\": \"\\q\"}",
            b"\"\\ud800x\"",
            b"",
            b"  ",
            b"1 2",
        ] {
            let err = parse(bad).unwrap_err().to_string();
            assert!(
                err.contains("offset") || err.contains("end of input") || err.contains("input"),
                "error for {bad:?} lacks location: {err}"
            );
        }
    }

    #[test]
    fn depth_bomb_is_refused_not_a_stack_overflow() {
        let doc = vec![b'['; 100_000];
        let err = parse(&doc).unwrap_err().to_string();
        assert!(err.contains("nesting"), "{err}");
    }

    /// Random byte soup must never panic — mirrors the corruption fuzz
    /// contract every binary loader is held to.
    #[test]
    fn random_bytes_never_panic() {
        let mut rng = Rng::new(0x150_F00D);
        for _ in 0..2000 {
            let len = rng.below(200);
            let bytes: Vec<u8> = (0..len).map(|_| (rng.next_u64() & 0xFF) as u8).collect();
            let _ = parse(&bytes); // Ok or Err both fine; panic is the only failure.
        }
        // And mutated valid documents.
        let base = br#"{"shards": [{"file": "s", "bytes": 1, "crc32c": 2}], "seed": 42}"#;
        for i in 0..base.len() {
            for bit in 0..8 {
                let mut doc = base.to_vec();
                doc[i] ^= 1 << bit;
                let _ = parse(&doc);
            }
        }
    }
}
