//! Pure-Rust per-model forward oracles — a second, independent
//! implementation of every [`ModelKind`] used to cross-validate the fast
//! native kernels (and, for Sage, the AOT artifacts end-to-end: tensorize
//! → HLO execute must agree with this, see `rust/tests/integration.rs`).
//!
//! Oracle tiers: [`forward`] dispatches on `cfg.kind` to a deliberately
//! naive triple-loop implementation of that architecture's layer recipe
//! ([`forward_sage`], [`forward_gcn`], [`forward_gin`]); the fast paths in
//! `train/cpu/{sage,gcn,gin}.rs` are property-tested against these across
//! the graph zoo, and their backwards against central finite differences.
//! The Sage oracle additionally anchors the bitwise chain: `forward_sage`
//! is byte-for-byte the pre-refactor `reference::forward`, and the
//! retained `cpu::sage::*_scalar` path is asserted bit-identical to the
//! packed kernels.

use super::tensorize::TrainBatch;
use crate::runtime::{ModelConfig, ParamSet};
use crate::train::model::ModelKind;

/// Forward pass over a tensorized batch; returns logits `[n_pad, classes]`
/// (row-major). Dispatches on the model kind.
pub fn forward(cfg: &ModelConfig, params: &ParamSet, batch: &TrainBatch) -> Vec<f32> {
    match cfg.kind {
        ModelKind::Sage => forward_sage(cfg, params, batch),
        ModelKind::Gcn => forward_gcn(cfg, params, batch),
        ModelKind::Gin => forward_gin(cfg, params, batch),
    }
}

/// Naive GraphSAGE forward (the original reference — unchanged through the
/// `GnnModel` refactor, which is what pins the Sage trajectory).
pub fn forward_sage(cfg: &ModelConfig, params: &ParamSet, batch: &TrainBatch) -> Vec<f32> {
    let n = batch.n_pad;
    let feat = batch.tensors[0].as_f32();
    let src = batch.tensors[1].as_i32();
    let dst = batch.tensors[2].as_i32();
    let emask = batch.tensors[3].as_f32();
    let mut h: Vec<f32> = feat.to_vec();
    let mut d_in = cfg.feat_dim;
    for l in 0..cfg.layers {
        let d_out = if l == cfg.layers - 1 { cfg.classes } else { cfg.hidden };
        let hdim = cfg.hidden;
        let w = &params.data[4 * l];
        let b = &params.data[4 * l + 1];
        let u = &params.data[4 * l + 2];
        let c = &params.data[4 * l + 3];
        // msg = relu(h @ W + b): [n, hdim]
        let mut msg = vec![0f32; n * hdim];
        for i in 0..n {
            for k in 0..d_in {
                let x = h[i * d_in + k];
                if x != 0.0 {
                    for j in 0..hdim {
                        msg[i * hdim + j] += x * w[k * hdim + j];
                    }
                }
            }
            for j in 0..hdim {
                let v = msg[i * hdim + j] + b[j];
                msg[i * hdim + j] = if v > 0.0 { v } else { 0.0 };
            }
        }
        // agg = weighted segment mean over incoming messages.
        let mut agg = vec![0f32; n * hdim];
        let mut cnt = vec![0f32; n];
        for e in 0..batch.e_pad {
            let wgt = emask[e];
            if wgt == 0.0 {
                continue;
            }
            let (s, d) = (src[e] as usize, dst[e] as usize);
            for j in 0..hdim {
                agg[d * hdim + j] += wgt * msg[s * hdim + j];
            }
            cnt[d] += wgt;
        }
        for i in 0..n {
            let denom = cnt[i].max(1e-9);
            for j in 0..hdim {
                agg[i * hdim + j] /= denom;
            }
        }
        // h' = concat(agg, h) @ U + c: [n, d_out]
        let mut out = vec![0f32; n * d_out];
        for i in 0..n {
            for j in 0..d_out {
                out[i * d_out + j] = c[j];
            }
            for k in 0..hdim {
                let x = agg[i * hdim + k];
                if x != 0.0 {
                    for j in 0..d_out {
                        out[i * d_out + j] += x * u[k * d_out + j];
                    }
                }
            }
            for k in 0..d_in {
                let x = h[i * d_in + k];
                if x != 0.0 {
                    for j in 0..d_out {
                        out[i * d_out + j] += x * u[(hdim + k) * d_out + j];
                    }
                }
            }
        }
        h = out;
        d_in = d_out;
    }
    h
}

/// Naive GCN forward: symmetric-normalized aggregation with an implicit
/// self-loop (`ĉ_v = 1 + Σ_{e→v} w_e`), add combine, ReLU on every layer
/// but the last. Parameters per layer: `W [d_in, d_out]`, `b [d_out]`.
pub fn forward_gcn(cfg: &ModelConfig, params: &ParamSet, batch: &TrainBatch) -> Vec<f32> {
    let n = batch.n_pad;
    let feat = batch.tensors[0].as_f32();
    let src = batch.tensors[1].as_i32();
    let dst = batch.tensors[2].as_i32();
    let emask = batch.tensors[3].as_f32();
    // ĉ depends only on the edge weights, not the layer.
    let mut denom = vec![1f32; n];
    for e in 0..batch.e_pad {
        let w = emask[e];
        if w != 0.0 {
            denom[dst[e] as usize] += w;
        }
    }
    let mut h: Vec<f32> = feat.to_vec();
    let mut d_in = cfg.feat_dim;
    for l in 0..cfg.layers {
        let d_out = if l == cfg.layers - 1 { cfg.classes } else { cfg.hidden };
        let w = &params.data[2 * l];
        let b = &params.data[2 * l + 1];
        // comb = sym-normalized neighbor sum + h/ĉ.
        let mut comb = vec![0f32; n * d_in];
        for e in 0..batch.e_pad {
            let wgt = emask[e];
            if wgt == 0.0 {
                continue;
            }
            let (s, d) = (src[e] as usize, dst[e] as usize);
            let f = wgt / (denom[s] * denom[d]).sqrt();
            for j in 0..d_in {
                comb[d * d_in + j] += f * h[s * d_in + j];
            }
        }
        for i in 0..n {
            let inv = 1.0 / denom[i];
            for j in 0..d_in {
                comb[i * d_in + j] += inv * h[i * d_in + j];
            }
        }
        // out = comb @ W + b, ReLU except on logits.
        let mut out = vec![0f32; n * d_out];
        for i in 0..n {
            for j in 0..d_out {
                out[i * d_out + j] = b[j];
            }
            for k in 0..d_in {
                let x = comb[i * d_in + k];
                if x != 0.0 {
                    for j in 0..d_out {
                        out[i * d_out + j] += x * w[k * d_out + j];
                    }
                }
            }
            if l != cfg.layers - 1 {
                for j in 0..d_out {
                    if out[i * d_out + j] < 0.0 {
                        out[i * d_out + j] = 0.0;
                    }
                }
            }
        }
        h = out;
        d_in = d_out;
    }
    h
}

/// Naive GIN forward: weighted sum aggregation, `(1+ε)·self` combine, and
/// a 2-layer MLP with ReLU on the hidden (output linear). Parameters per
/// layer: `ε [1]`, `W1 [d_in, H]`, `b1 [H]`, `W2 [H, d_out]`, `b2 [d_out]`.
pub fn forward_gin(cfg: &ModelConfig, params: &ParamSet, batch: &TrainBatch) -> Vec<f32> {
    let n = batch.n_pad;
    let feat = batch.tensors[0].as_f32();
    let src = batch.tensors[1].as_i32();
    let dst = batch.tensors[2].as_i32();
    let emask = batch.tensors[3].as_f32();
    let hdim = cfg.hidden;
    let mut h: Vec<f32> = feat.to_vec();
    let mut d_in = cfg.feat_dim;
    for l in 0..cfg.layers {
        let d_out = if l == cfg.layers - 1 { cfg.classes } else { cfg.hidden };
        let eps = params.data[5 * l][0];
        let w1 = &params.data[5 * l + 1];
        let b1 = &params.data[5 * l + 2];
        let w2 = &params.data[5 * l + 3];
        let b2 = &params.data[5 * l + 4];
        // comb = (1+ε)·h + weighted neighbor sum.
        let mut comb = vec![0f32; n * d_in];
        for e in 0..batch.e_pad {
            let wgt = emask[e];
            if wgt == 0.0 {
                continue;
            }
            let (s, d) = (src[e] as usize, dst[e] as usize);
            for j in 0..d_in {
                comb[d * d_in + j] += wgt * h[s * d_in + j];
            }
        }
        for i in 0..n * d_in {
            comb[i] += (1.0 + eps) * h[i];
        }
        // hid = relu(comb @ W1 + b1).
        let mut hid = vec![0f32; n * hdim];
        for i in 0..n {
            for k in 0..d_in {
                let x = comb[i * d_in + k];
                if x != 0.0 {
                    for j in 0..hdim {
                        hid[i * hdim + j] += x * w1[k * hdim + j];
                    }
                }
            }
            for j in 0..hdim {
                let v = hid[i * hdim + j] + b1[j];
                hid[i * hdim + j] = if v > 0.0 { v } else { 0.0 };
            }
        }
        // out = hid @ W2 + b2 (linear).
        let mut out = vec![0f32; n * d_out];
        for i in 0..n {
            for j in 0..d_out {
                out[i * d_out + j] = b2[j];
            }
            for k in 0..hdim {
                let x = hid[i * hdim + k];
                if x != 0.0 {
                    for j in 0..d_out {
                        out[i * d_out + j] += x * w2[k * d_out + j];
                    }
                }
            }
        }
        h = out;
        d_in = d_out;
    }
    h
}

/// Index of the largest entry of `row`, NaN-safe: NaN entries never win,
/// ties break deterministically to the lowest index, and an all-NaN (or
/// empty) row falls back to 0. Callers that score predictions must check
/// `row[argmax(row)]` is not NaN before counting a hit, so an all-NaN row
/// never scores as "correct class 0". Shared by the reference and native
/// backends so their `correct` counts agree.
pub fn argmax(row: &[f32]) -> usize {
    let mut best = 0usize;
    let mut best_v = f32::NEG_INFINITY;
    let mut found = false;
    for (j, &x) in row.iter().enumerate() {
        if x.is_nan() {
            continue;
        }
        if !found || x > best_v {
            best = j;
            best_v = x;
            found = true;
        }
    }
    best
}

/// DAR-weighted cross-entropy loss + weight sum + correct count, matching
/// the artifact's train-step outputs (`loss_sum`, `weight_sum`, `correct`).
pub fn loss_and_metrics(
    cfg: &ModelConfig,
    logits: &[f32],
    batch: &TrainBatch,
) -> (f64, f64, f64) {
    let n = batch.n_pad;
    let c = cfg.classes;
    let dar = batch.tensors[4].as_f32();
    let labels = batch.tensors[5].as_i32();
    let tmask = batch.tensors[6].as_f32();
    let (mut loss, mut wsum, mut correct) = (0f64, 0f64, 0f64);
    for i in 0..n {
        let w = (dar[i] * tmask[i]) as f64;
        let row = &logits[i * c..(i + 1) * c];
        let maxv = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        if tmask[i] > 0.0 {
            let am = argmax(row);
            // NaN at the winner ⇒ no real prediction ⇒ never correct.
            if !row[am].is_nan() && am as i32 == labels[i] {
                correct += tmask[i] as f64;
            }
        }
        if w > 0.0 {
            let logz =
                maxv as f64 + row.iter().map(|&x| ((x - maxv) as f64).exp()).sum::<f64>().ln();
            let ce = logz - row[labels[i] as usize] as f64;
            loss += w * ce;
            wsum += w;
        }
    }
    (loss, wsum, correct)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::features::{synthesize, FeatureParams};
    use crate::graph::generators::barabasi_albert;
    use crate::partition::{dar_weights, random::RandomVertexCut, Reweighting, VertexCut};
    use crate::train::tensorize::tensorize_partition;
    use crate::util::rng::Rng;

    fn setup(layers: usize) -> (ModelConfig, ParamSet, TrainBatch) {
        let mut rng = Rng::new(80);
        let g = barabasi_albert(120, 3, &mut rng);
        let comm: Vec<u32> = (0..120).map(|i| (i % 3) as u32).collect();
        let nd = synthesize(&comm, 3, &FeatureParams { dim: 6, ..Default::default() }, &mut rng);
        let vc = VertexCut::create(&g, 2, &RandomVertexCut, &mut rng);
        let w = dar_weights(&g, &vc, Reweighting::Dar);
        let batch = tensorize_partition(&vc.parts[0], &nd, &w[0], 128, 1024).unwrap();
        let cfg = ModelConfig { kind: ModelKind::Sage, layers, feat_dim: 6, hidden: 8, classes: 3 };
        let params = ParamSet::init_glorot(&cfg, &mut rng);
        (cfg, params, batch)
    }

    #[test]
    fn forward_shapes_and_finiteness() {
        for layers in [1, 2, 3] {
            let (cfg, params, batch) = setup(layers);
            let logits = forward(&cfg, &params, &batch);
            assert_eq!(logits.len(), batch.n_pad * cfg.classes);
            assert!(logits.iter().all(|x| x.is_finite()));
        }
    }

    #[test]
    fn all_kinds_forward_shapes_and_finiteness() {
        for kind in ModelKind::ALL {
            for layers in [1, 2, 3] {
                let (mut cfg, _, batch) = setup(layers);
                cfg.kind = kind;
                let params = ParamSet::init_glorot(&cfg, &mut crate::util::rng::Rng::new(17));
                let logits = forward(&cfg, &params, &batch);
                assert_eq!(logits.len(), batch.n_pad * cfg.classes, "{kind:?} L{layers}");
                assert!(logits.iter().all(|x| x.is_finite()), "{kind:?} L{layers}");
            }
        }
    }

    #[test]
    fn all_kinds_loss_is_ln_c_at_zero_params() {
        // Every architecture's logits collapse to its (zero-initialized)
        // output bias at all-zero parameters -> CE = ln(C) per node.
        for kind in ModelKind::ALL {
            let (mut cfg, _, batch) = setup(2);
            cfg.kind = kind;
            let mut params = ParamSet::init_glorot(&cfg, &mut crate::util::rng::Rng::new(18));
            for p in &mut params.data {
                p.iter_mut().for_each(|x| *x = 0.0);
            }
            let logits = forward(&cfg, &params, &batch);
            let (loss, wsum, _) = loss_and_metrics(&cfg, &logits, &batch);
            let per_node = loss / wsum;
            assert!((per_node - (3f64).ln()).abs() < 1e-6, "{kind:?}: {per_node}");
        }
    }

    #[test]
    fn loss_is_ln_c_at_uniform_logits() {
        // With all-zero parameters, logits are 0 -> CE = ln(C) per node.
        let (cfg, mut params, batch) = setup(2);
        for p in &mut params.data {
            p.iter_mut().for_each(|x| *x = 0.0);
        }
        let logits = forward(&cfg, &params, &batch);
        let (loss, wsum, _) = loss_and_metrics(&cfg, &logits, &batch);
        let per_node = loss / wsum;
        assert!((per_node - (3f64).ln()).abs() < 1e-6, "{per_node}");
        assert!((wsum - batch.local_train_weight).abs() < 1e-4);
    }

    #[test]
    fn padding_rows_do_not_contribute() {
        let (cfg, params, batch) = setup(2);
        let logits = forward(&cfg, &params, &batch);
        let (l1, w1, c1) = loss_and_metrics(&cfg, &logits, &batch);
        // Scribble on padding logits: nothing changes.
        let mut logits2 = logits.clone();
        for i in batch.n_used..batch.n_pad {
            for j in 0..cfg.classes {
                logits2[i * cfg.classes + j] = 1e9;
            }
        }
        let (l2, w2, c2) = loss_and_metrics(&cfg, &logits2, &batch);
        assert_eq!((l1, w1, c1), (l2, w2, c2));
    }

    #[test]
    fn argmax_is_nan_safe_with_lowest_index_ties() {
        assert_eq!(argmax(&[1.0, 3.0, 2.0]), 1);
        // Ties break to the lowest index.
        assert_eq!(argmax(&[2.0, 5.0, 5.0, 1.0]), 1);
        // NaN entries never win, wherever they sit.
        assert_eq!(argmax(&[f32::NAN, 1.0, 2.0]), 2);
        assert_eq!(argmax(&[1.0, f32::NAN, 0.5]), 0);
        // All-NaN (and empty) rows fall back to 0 instead of panicking.
        assert_eq!(argmax(&[f32::NAN, f32::NAN]), 0);
        assert_eq!(argmax(&[]), 0);
        // -inf is a real value and can win over nothing else.
        assert_eq!(argmax(&[f32::NEG_INFINITY, f32::NAN]), 0);
    }

    #[test]
    fn loss_metrics_survive_nan_logits() {
        // A NaN logit row must not panic the argmax; the node simply scores
        // as (in)correct per the NaN-safe rule.
        let (cfg, params, batch) = setup(1);
        let mut logits = forward(&cfg, &params, &batch);
        for j in 0..cfg.classes {
            logits[j] = f32::NAN;
        }
        let (_, wsum, correct) = loss_and_metrics(&cfg, &logits, &batch);
        assert!(wsum.is_finite());
        assert!(correct.is_finite());
        // Fully-NaN logits predict nothing: zero correct, even for class-0
        // labels (the argmax fallback index must not score as a hit).
        let all_nan = vec![f32::NAN; logits.len()];
        let (_, _, c_nan) = loss_and_metrics(&cfg, &all_nan, &batch);
        assert_eq!(c_nan, 0.0);
    }

    #[test]
    fn isolated_in_batch_nodes_get_bias_plus_self() {
        // A node with no incoming kept edges aggregates zeros: its output is
        // c + h @ U_lower — check the aggregation half is exactly zero by
        // comparing against manual computation for a degree-0 padding row.
        let (cfg, params, batch) = setup(1);
        let logits = forward(&cfg, &params, &batch);
        // Padding rows have zero features and no edges: logits = c exactly.
        let c = &params.data[3];
        for i in batch.n_used..batch.n_pad {
            for j in 0..cfg.classes {
                assert!((logits[i * cfg.classes + j] - c[j]).abs() < 1e-6);
            }
        }
    }
}
