//! Fleet liveness policy + straggler detection for the proc runtime.
//!
//! [`HealthOptions`] is the coordinator's knob set: how long an epoch's
//! collect phase may run before pending ranks are declared lost
//! (`epoch_deadline`), how often the fleet is pinged between epochs
//! (`heartbeat_every`), and how much recovery the run will tolerate before
//! giving up (`max_recoveries` — the backstop against a deadline set
//! shorter than an honest epoch, which would otherwise respawn forever).
//!
//! [`StragglerMonitor`] turns the per-epoch phase telemetry the workers
//! report (protocol v5 [`StepPhases`]: compute with its forward/backward
//! split, plus serialize time) into straggler warnings: a rank whose step
//! took more than `straggler_factor ×` the fleet median (and more than an
//! absolute floor, so microsecond-scale jitter on tiny shards never
//! trips it) is logged — with the phase attribution, so the warn line says
//! *where* the rank lost the time — and counted. Detection only — a
//! slow-but-correct worker still contributes its partial sum, so recovery
//! would *change* nothing and risk plenty.

use super::proto::StepPhases;
use std::time::Duration;

/// Liveness + recovery policy for one multi-process run.
#[derive(Clone, Copy, Debug)]
pub struct HealthOptions {
    /// Longest a collect phase may wait with no pending result before the
    /// still-pending ranks are recovered (`None` = wait forever, the
    /// pre-fault-tolerance behavior).
    pub epoch_deadline: Option<Duration>,
    /// Ping every worker before the broadcast every N epochs (0 = off).
    /// Catches workers lost *between* epochs, where no read would
    /// otherwise notice until the next collect.
    pub heartbeat_every: usize,
    /// How long to wait for each `Pong`.
    pub heartbeat_timeout: Duration,
    /// A rank is a straggler when its compute time exceeds
    /// `straggler_factor ×` the fleet median of the epoch.
    pub straggler_factor: f64,
    /// …and exceeds this absolute floor (tiny shards finish in
    /// microseconds; 3× of nothing is still nothing).
    pub straggler_floor: Duration,
    /// Total worker recoveries the run tolerates before failing. Bounds
    /// the pathological case of an `epoch_deadline` shorter than an honest
    /// epoch, which would otherwise respawn healthy workers forever.
    pub max_recoveries: usize,
    /// Budget for one recovery: local respawn + re-handshake, or waiting
    /// for a remote worker to come back.
    pub recovery_timeout: Duration,
    /// Initial pause between remote reconnect attempts (doubles up to
    /// ~2s).
    pub reconnect_backoff: Duration,
}

impl Default for HealthOptions {
    fn default() -> Self {
        HealthOptions {
            epoch_deadline: None,
            heartbeat_every: 0,
            heartbeat_timeout: Duration::from_secs(5),
            straggler_factor: 3.0,
            straggler_floor: Duration::from_millis(100),
            max_recoveries: 16,
            recovery_timeout: Duration::from_secs(30),
            reconnect_backoff: Duration::from_millis(100),
        }
    }
}

/// Median-based straggler detection over the per-epoch compute telemetry.
/// The scratch buffer is reused, so observing an epoch allocates nothing
/// in steady state.
#[derive(Default)]
pub struct StragglerMonitor {
    scratch: Vec<f64>,
    /// Total straggler observations over the run (rank-epochs).
    pub flagged: u64,
}

impl StragglerMonitor {
    pub fn new() -> StragglerMonitor {
        StragglerMonitor::default()
    }

    /// Feed one epoch's `(rank, compute_seconds)` telemetry; logs and
    /// counts every rank beyond the threshold. Returns how many were
    /// flagged this epoch.
    pub fn observe<I>(&mut self, factor: f64, floor: Duration, epoch: usize, times: I) -> usize
    where
        I: Iterator<Item = (usize, f64)> + Clone,
    {
        self.observe_phases(
            factor,
            floor,
            epoch,
            times.map(|(rank, t)| (rank, StepPhases { compute_seconds: t, ..Default::default() })),
        )
    }

    /// Feed one epoch's full `(rank, StepPhases)` telemetry. Thresholding
    /// is on `compute_seconds` (the signal that stalls the collect phase);
    /// the warn line attributes the loss to forward vs backward vs
    /// serialize so an operator can tell a thermal-throttled GEMM from a
    /// slow disk/NIC without attaching a profiler. Returns how many ranks
    /// were flagged this epoch.
    pub fn observe_phases<I>(
        &mut self,
        factor: f64,
        floor: Duration,
        epoch: usize,
        phases: I,
    ) -> usize
    where
        I: Iterator<Item = (usize, StepPhases)> + Clone,
    {
        self.scratch.clear();
        self.scratch.extend(phases.clone().map(|(_, p)| p.compute_seconds));
        if self.scratch.len() < 2 {
            return 0; // a fleet of one has no peers to lag behind
        }
        self.scratch.sort_by(|a, b| a.total_cmp(b));
        let median = self.scratch[self.scratch.len() / 2];
        let threshold = (median * factor).max(floor.as_secs_f64());
        let mut n = 0;
        for (rank, p) in phases {
            if p.compute_seconds > threshold {
                crate::log_warn!(
                    "epoch {epoch}: rank {rank} straggling — {:.1}ms vs fleet median {:.1}ms \
                     (fwd {:.1}ms, bwd {:.1}ms, ser {:.1}ms)",
                    p.compute_seconds * 1e3,
                    median * 1e3,
                    p.forward_seconds * 1e3,
                    p.backward_seconds * 1e3,
                    p.serialize_seconds * 1e3
                );
                n += 1;
            }
        }
        self.flagged += n as u64;
        n
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flags_only_ranks_beyond_factor_and_floor() {
        let mut mon = StragglerMonitor::new();
        let floor = Duration::from_millis(100);
        // Rank 2 is 30× the median and above the floor: flagged.
        let times = [(0usize, 0.01f64), (1, 0.012), (2, 0.3)];
        assert_eq!(mon.observe(3.0, floor, 0, times.iter().copied()), 1);
        assert_eq!(mon.flagged, 1);
        // Everyone under the absolute floor: jitter, not stragglers.
        let tiny = [(0usize, 1e-5f64), (1, 1e-5), (2, 9e-5)];
        assert_eq!(mon.observe(3.0, floor, 1, tiny.iter().copied()), 0);
        // Uniform fleet: nobody flagged no matter the factor.
        let even = [(0usize, 0.2f64), (1, 0.21), (2, 0.2)];
        assert_eq!(mon.observe(1.5, floor, 2, even.iter().copied()), 0);
        assert_eq!(mon.flagged, 1);
    }

    #[test]
    fn observe_phases_thresholds_on_compute_seconds() {
        let mut mon = StragglerMonitor::new();
        let floor = Duration::from_millis(100);
        let mk = |c: f64| StepPhases {
            compute_seconds: c,
            forward_seconds: c * 0.6,
            backward_seconds: c * 0.4,
            serialize_seconds: 0.001,
            peak_workspace_bytes: 1 << 20,
        };
        let fleet = [(0usize, mk(0.01)), (1, mk(0.012)), (2, mk(0.5))];
        assert_eq!(mon.observe_phases(3.0, floor, 0, fleet.iter().copied()), 1);
        assert_eq!(mon.flagged, 1);
        // A rank slow only in serialize does not trip the compute threshold.
        let wire_bound = [
            (0usize, mk(0.01)),
            (1, StepPhases { serialize_seconds: 5.0, ..mk(0.011) }),
            (2, mk(0.012)),
        ];
        assert_eq!(mon.observe_phases(3.0, floor, 1, wire_bound.iter().copied()), 0);
    }

    #[test]
    fn single_worker_fleet_never_flags() {
        let mut mon = StragglerMonitor::new();
        let one = [(0usize, 99.0f64)];
        assert_eq!(mon.observe(3.0, Duration::from_millis(1), 0, one.iter().copied()), 0);
    }
}
