//! The experiment grid: which (dataset, partition-count) cells the paper's
//! evaluation visits. `cofree emit-bucket-spec` derives the AOT shape
//! buckets from exactly this grid, so `make artifacts` always covers what
//! the benches run.

use crate::graph::datasets;
use crate::runtime::{ArtifactKind, ArtifactSpec, ModelConfig};
use crate::train::bucket::bucket_shapes;
use std::collections::BTreeMap;
use std::path::PathBuf;

/// Deterministic seed used by all benches (10-trial std-devs fork from it).
pub const BENCH_SEED: u64 = 42;
/// Dataset scale used by the timing benches (Table 1, Figures 2–3).
pub const BENCH_SCALE: f64 = 1.0;
/// Dataset scale used by the accuracy benches (Tables 2–4, Figures 4–5) —
/// smaller because they train to convergence.
pub const ACC_SCALE: f64 = 0.25;

/// One dataset's partition sweep.
#[derive(Clone, Copy, Debug)]
pub struct GridEntry {
    pub dataset: &'static str,
    pub scale: f64,
    pub partitions: &'static [usize],
}

/// Partition counts covering Table 1 (2/4, 5/10, 3/6), Figure 3's sweep and
/// Figure 5 / Tables 3–4's large-p settings.
pub fn train_grid() -> Vec<GridEntry> {
    vec![
        GridEntry {
            dataset: "reddit-sim",
            scale: BENCH_SCALE,
            partitions: &[1, 2, 3, 4, 6, 8, 16, 32, 64, 128, 256],
        },
        GridEntry {
            dataset: "products-sim",
            scale: BENCH_SCALE,
            partitions: &[1, 2, 4, 5, 8, 10, 16, 32, 64, 128, 256],
        },
        GridEntry {
            dataset: "yelp-sim",
            scale: BENCH_SCALE,
            partitions: &[1, 2, 3, 4, 6, 8, 16, 32, 64, 128, 256],
        },
        // Figure 2: multi-node papers100M stand-in, 192 partitions only.
        GridEntry { dataset: "papers-sim", scale: BENCH_SCALE, partitions: &[192] },
        // Accuracy experiments run at a smaller scale: cover the same p
        // values on the shrunken graphs.
        GridEntry {
            dataset: "reddit-sim",
            scale: ACC_SCALE,
            partitions: &[1, 2, 4, 8, 16, 32, 64, 128, 256],
        },
        GridEntry {
            dataset: "products-sim",
            scale: ACC_SCALE,
            partitions: &[1, 2, 4, 8, 16, 32, 64, 128, 256],
        },
        GridEntry {
            dataset: "yelp-sim",
            scale: ACC_SCALE,
            partitions: &[1, 2, 4, 8, 16, 32, 64, 128, 256],
        },
    ]
}

/// Cells where the *baselines'* halo compute graphs are executed for the
/// timing comparisons (Table 1 + Figure 2). Halo subgraphs are larger than
/// vertex-cut partitions (owned ∪ halo nodes, intra + cut edges), so they
/// get their own buckets, sized from the deterministic LDG edge cut that
/// `experiments::measure_baseline_compute` reproduces at run time.
pub const BASELINE_CELLS: [(&str, &[usize]); 4] = [
    ("reddit-sim", &[2, 4]),
    ("products-sim", &[5, 10]),
    ("yelp-sim", &[3, 6]),
    ("papers-sim", &[192]),
];

/// Datasets that need full-graph eval artifacts (accuracy tables/curves).
pub fn eval_grid() -> Vec<(&'static str, f64)> {
    vec![
        ("reddit-sim", BENCH_SCALE),
        ("products-sim", BENCH_SCALE),
        ("yelp-sim", BENCH_SCALE),
        ("reddit-sim", ACC_SCALE),
        ("products-sim", ACC_SCALE),
        ("yelp-sim", ACC_SCALE),
    ]
}

/// Enumerate every artifact bucket the grid needs (deduplicated), as
/// `bucket ...` spec lines for `compile/aot.py`.
pub fn bucket_spec_lines() -> anyhow::Result<Vec<String>> {
    // name -> line; BTreeMap for stable output order.
    let mut lines: BTreeMap<String, String> = BTreeMap::new();
    let mut push = |model: &ModelConfig, n_pad: usize, e_pad: usize, kind: ArtifactKind| {
        let name = ArtifactSpec::bucket_name("sage", model, n_pad, e_pad, kind);
        let spec = ArtifactSpec {
            name: name.clone(),
            kind,
            model: *model,
            n_pad,
            e_pad,
            file: PathBuf::new(),
        };
        lines.entry(name).or_insert_with(|| spec.spec_line());
    };
    for entry in train_grid() {
        let ds = datasets::build(entry.dataset, entry.scale, BENCH_SEED)?;
        let model = crate::train::engine::model_config(&ds);
        let (n, m) = (ds.graph.num_nodes(), ds.graph.num_edges());
        for &p in entry.partitions {
            let (n_pad, e_pad) = bucket_shapes(n, m, p);
            push(&model, n_pad, e_pad, ArtifactKind::Train);
        }
    }
    for (name, scale) in eval_grid() {
        let ds = datasets::build(name, scale, BENCH_SEED)?;
        let model = crate::train::engine::model_config(&ds);
        let (n, m) = (ds.graph.num_nodes(), ds.graph.num_edges());
        let (n_pad, e_pad) = bucket_shapes(n, m, 1);
        push(&model, n_pad, e_pad, ArtifactKind::Eval);
    }
    // Halo-compute buckets for the timing baselines, sized from the exact
    // deterministic edge cut the benches will build.
    for (name, ps) in BASELINE_CELLS {
        let ds = datasets::build(name, BENCH_SCALE, BENCH_SEED)?;
        let model = crate::train::engine::model_config(&ds);
        for &p in ps {
            let mut rng = crate::util::rng::Rng::new(BENCH_SEED);
            let ec = crate::partition::LdgEdgeCut::default().partition(&ds.graph, p, &mut rng);
            let (mut n_max, mut e_max) = (0usize, 0usize);
            for i in 0..p {
                let n_i = ec.owned[i].len() + ec.halos[i].len();
                // Edges incident to owned nodes: intra once + cut once.
                let deg_sum: usize =
                    ec.owned[i].iter().map(|&v| ds.graph.degree(v) as usize).sum();
                let e_i = deg_sum - ec.parts[i].local.num_edges();
                n_max = n_max.max(n_i);
                e_max = e_max.max(e_i);
            }
            let (n_pad, e_pad) = crate::train::bucket::pad_explicit(
                (n_max as f64 * 1.05) as usize + 1,
                2 * ((e_max as f64 * 1.05) as usize + 1),
            );
            push(&model, n_pad, e_pad, ArtifactKind::Train);
        }
    }
    Ok(lines.into_values().collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_covers_table1_cells() {
        let g = train_grid();
        let get = |name: &str| g.iter().find(|e| e.dataset == name && e.scale == BENCH_SCALE).unwrap();
        assert!(get("reddit-sim").partitions.contains(&2));
        assert!(get("reddit-sim").partitions.contains(&4));
        assert!(get("products-sim").partitions.contains(&5));
        assert!(get("products-sim").partitions.contains(&10));
        assert!(get("yelp-sim").partitions.contains(&3));
        assert!(get("yelp-sim").partitions.contains(&6));
        assert!(get("papers-sim").partitions.contains(&192));
    }

    #[test]
    fn bucket_lines_dedupe_and_parse() {
        // Use tiny scales to keep the test fast: rebuild the function's core
        // over a reduced grid by just calling it (datasets are cached? no —
        // they are cheap at these sizes; papers-sim dominates at ~1s).
        let lines = bucket_spec_lines().unwrap();
        assert!(lines.len() > 10, "expected a real ladder, got {}", lines.len());
        let mut seen = std::collections::HashSet::new();
        for l in &lines {
            assert!(l.starts_with("bucket name=sage-"), "{l}");
            assert!(seen.insert(l.clone()), "duplicate line {l}");
        }
        // Both kinds appear.
        assert!(lines.iter().any(|l| l.contains("kind=train")));
        assert!(lines.iter().any(|l| l.contains("kind=eval")));
    }
}
