"""Layer-1 Pallas kernels: the dense compute hot spot of GraphSAGE.

After CoFree-GNN removes all embedding communication, a training iteration is
dominated by the per-layer dense transforms ``relu(x @ W + b)`` (message
transform) and ``concat(agg, h) @ U + c`` (update) — see DESIGN.md
§Hardware-Adaptation.  These are implemented here as tiled Pallas matmul
kernels with a classic TPU structure:

* 3-D grid ``(M/bm, N/bn, K/bk)`` with the K dimension innermost and
  sequential, accumulating into the output block — the MXU-feeding schedule
  that Mosaic double-buffers on real hardware;
* ``BlockSpec``s express the HBM->VMEM tiling: an ``(bm, bk)`` tile of ``x``
  and a ``(bk, bn)`` tile of ``w`` are resident per step
  (``bm*bk + bk*bn + bm*bn`` f32 words of VMEM);
* ``preferred_element_type=jnp.float32`` keeps f32 accumulation (bf16 inputs
  would hit the MXU natively on TPU).

Autodiff: ``pallas_call`` has no automatic VJP, so the public entry points
(:func:`matmul`, :func:`relu_linear`) carry ``jax.custom_vjp`` whose backward
passes are themselves Pallas matmuls — the gradient hot path runs through the
same kernel.

Everything is lowered with ``interpret=True``: the CPU PJRT plugin cannot run
Mosaic custom-calls (see /opt/xla-example/README.md); on TPU the same code
compiles to MXU kernels.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes. On TPU, 128 matches both the MXU systolic dimension
# and the lane width and would be the right default. Under interpret=True
# (this build's only execution mode) every grid step pays interpreter
# dispatch overhead, so the CPU-tuned defaults below use much larger tiles
# to shrink the grid (see EXPERIMENTS.md §Perf for the sweep). Override with
# COFREE_BLOCK_M/N/K; set 128/128/128 to inspect the TPU-shaped schedule.
import os as _os

def _env_int(name, default):
    try:
        return int(_os.environ.get(name, default))
    except ValueError:
        return default

BLOCK_M = _env_int("COFREE_BLOCK_M", 16384)
BLOCK_N = _env_int("COFREE_BLOCK_N", 4096)
BLOCK_K = _env_int("COFREE_BLOCK_K", 16384)


def _maybe_pad2(x, r, c):
    """Pad a 2-D array only when needed (interpret mode: pads are copies)."""
    if x.shape == (r, c):
        return x
    return jnp.pad(x, ((0, r - x.shape[0]), (0, c - x.shape[1])))


def _ceil_to(x: int, b: int) -> int:
    return (x + b - 1) // b * b


def _mm_kernel(x_ref, w_ref, o_ref):
    """One (bm, bn) output tile; K arrives in bk-sized steps (grid dim 2)."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)


def _pallas_mm(x, w, bm=BLOCK_M, bn=BLOCK_N, bk=BLOCK_K):
    """Raw tiled matmul: pads to tile multiples, runs the kernel, unpads."""
    m, kdim = x.shape
    kdim2, n = w.shape
    assert kdim == kdim2, f"shape mismatch {x.shape} @ {w.shape}"
    bm = min(bm, _ceil_to(m, 8))
    bn = min(bn, _ceil_to(n, 8))
    bk = min(bk, _ceil_to(kdim, 8))
    mp, np_, kp = _ceil_to(m, bm), _ceil_to(n, bn), _ceil_to(kdim, bk)
    xp = _maybe_pad2(x, mp, kp)
    wp = _maybe_pad2(w, kp, np_)
    out = pl.pallas_call(
        _mm_kernel,
        grid=(mp // bm, np_ // bn, kp // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(xp, wp)
    return out if (mp, np_) == (m, n) else out[:m, :n]


# ---------------------------------------------------------------------------
# matmul: plain x @ w with Pallas forward and backward.
# ---------------------------------------------------------------------------


@jax.custom_vjp
def matmul(x, w):
    """``x @ w`` computed by the tiled Pallas kernel (f32)."""
    return _pallas_mm(x, w)


def _matmul_fwd(x, w):
    return _pallas_mm(x, w), (x, w)


def _matmul_bwd(res, g):
    x, w = res
    # dx = g @ w^T ; dw = x^T @ g — both through the same Pallas kernel.
    return _pallas_mm(g, w.T), _pallas_mm(x.T, g)


matmul.defvjp(_matmul_fwd, _matmul_bwd)


# ---------------------------------------------------------------------------
# relu_linear: fused relu(x @ w + b).
# ---------------------------------------------------------------------------


def _mm_bias_relu_kernel(x_ref, w_ref, b_ref, o_ref, *, nk):
    """Fused epilogue: on the last K step apply bias + ReLU in-register."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _epilogue():
        o_ref[...] = jnp.maximum(o_ref[...] + b_ref[...], 0.0)


def _pallas_mm_bias_relu(x, w, b, bm=BLOCK_M, bn=BLOCK_N, bk=BLOCK_K):
    m, kdim = x.shape
    _, n = w.shape
    bm = min(bm, _ceil_to(m, 8))
    bn = min(bn, _ceil_to(n, 8))
    bk = min(bk, _ceil_to(kdim, 8))
    mp, np_, kp = _ceil_to(m, bm), _ceil_to(n, bn), _ceil_to(kdim, bk)
    xp = _maybe_pad2(x, mp, kp)
    wp = _maybe_pad2(w, kp, np_)
    bp = _maybe_pad2(b.reshape(1, -1), 1, np_)
    out = pl.pallas_call(
        partial(_mm_bias_relu_kernel, nk=kp // bk),
        grid=(mp // bm, np_ // bn, kp // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(xp, wp, bp)
    return out if (mp, np_) == (m, n) else out[:m, :n]


@jax.custom_vjp
def relu_linear(x, w, b):
    """Fused ``relu(x @ w + b)`` with Pallas forward and backward."""
    return _pallas_mm_bias_relu(x, w, b)


def _relu_linear_fwd(x, w, b):
    y = _pallas_mm_bias_relu(x, w, b)
    # Save the activation mask (y > 0) instead of the pre-activation: smaller
    # residual and exactly what the backward needs.
    return y, (x, w, y > 0.0)


def _relu_linear_bwd(res, g):
    x, w, mask = res
    gm = jnp.where(mask, g, 0.0)
    dx = _pallas_mm(gm, w.T)
    dw = _pallas_mm(x.T, gm)
    db = gm.sum(axis=0)
    return dx, dw, db


relu_linear.defvjp(_relu_linear_fwd, _relu_linear_bwd)
