//! The training loop (Algorithm 1 of the paper), generic over the
//! execution [`Backend`].
//!
//! ```text
//! partition G  →  tensorize per partition  →  prepare workers once
//! while not converged:
//!     for each worker i in parallel:   (communication-free — no embedding
//!         pick DropEdge mask k_i;       exchange, ever)
//!         run train_step on partition i
//!     sum gradients (the only cross-worker traffic)
//!     params ← Adam(params, Σ grads / |V_train|)
//! ```
//!
//! The engine implements the loop once; the backend supplies `train_step`.
//! With the default features that is [`CpuBackend`] — the native rayon
//! forward/backward, workers genuinely in parallel on the host. With
//! `--features xla` it is [`XlaBackend`] — the AOT-compiled PJRT artifacts,
//! workers sequential on the single device. Either way we time each
//! worker's step individually and report the *parallel-machine* iteration
//! time `max_i(compute_i) + allreduce + optimizer`, which is what Table 1
//! measures on real hardware; the all-reduce term is supplied by the caller
//! (from `simnet`, or 0 for in-process semantics).
//!
//! Determinism: DropEdge mask picks are pre-drawn in worker order, worker
//! outputs return in that order, and the gradient fold is sequential — so
//! the training trajectory is bit-identical for any rayon pool size.

use super::allreduce::GradAccumulator;
use super::backend::{Backend, WorkerMeta};
use super::checkpoint::{AsyncCheckpointer, TrainCheckpoint};
use super::metrics::{EpochStats, History};
use super::optimizer::{Adam, Optimizer, Sgd};
use super::tensorize::{tensorize_full_eval, tensorize_full_train, tensorize_partition, TrainBatch};
use crate::graph::Dataset;
use crate::partition::{dar_weights, Reweighting, VertexCut};
use crate::runtime::{ArtifactKind, ModelConfig, ParamSet, TrainOut};
use crate::train::model::{ModelKind, Precision};
use crate::train::cpu::CpuBackend;
use crate::util::rng::Rng;
use crate::util::timer::PhaseTimer;
use anyhow::{ensure, Context, Result};
use std::path::PathBuf;
use std::time::Instant;

#[cfg(feature = "xla")]
use {
    super::dropedge::MaskBank,
    super::tensorize::EvalBatch,
    crate::runtime::{Executor, Registry, RuntimeClient},
    std::collections::HashMap,
    std::path::Path,
    std::rc::Rc,
};

/// Training hyperparameters.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub epochs: usize,
    pub lr: f32,
    /// Evaluate every N epochs (0 = only at the end).
    pub eval_every: usize,
    /// DropEdge-K: `Some((K, drop_ratio))`.
    pub dropedge: Option<(usize, f64)>,
    pub seed: u64,
    pub use_adam: bool,
    /// Modeled all-reduce seconds added to each iteration's reported time
    /// (0.0 for pure in-process runs; benches pass the simnet value).
    pub allreduce_seconds: f64,
    /// Log every N epochs (0 = silent).
    pub log_every: usize,
    /// Snapshot a resumable checkpoint every N epochs (0 = off). The
    /// writes happen on a background thread ([`AsyncCheckpointer`]) and
    /// never block or allocate in the epoch loop.
    pub checkpoint_every: usize,
    /// Where periodic checkpoints land (atomic rename: the file is always
    /// a complete snapshot). Required when `checkpoint_every > 0`.
    pub checkpoint_path: Option<PathBuf>,
    /// Run-ledger path (`cofree train --metrics-out metrics.jsonl`): one
    /// durable JSON line per epoch (`None` = no ledger). The CLI appends
    /// the final summary record after training returns — see
    /// [`crate::obs::ledger`].
    pub metrics_out: Option<PathBuf>,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            epochs: 100,
            lr: 0.01,
            eval_every: 10,
            dropedge: None,
            seed: 0,
            use_adam: true,
            allreduce_seconds: 0.0,
            log_every: 0,
            checkpoint_every: 0,
            checkpoint_path: None,
            metrics_out: None,
        }
    }
}

/// Histogram bucket bounds for epoch wall-clock (seconds): log-spaced from
/// sub-millisecond toy graphs to minutes-long epochs; the last bucket is
/// the overflow.
const EPOCH_SECONDS_BOUNDS: &[f64] =
    &[0.001, 0.005, 0.02, 0.1, 0.5, 2.0, 10.0, 60.0, 300.0];

/// How the workers are scheduled each iteration.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RunMode {
    /// Algorithm 1: every partition contributes every iteration.
    AllParts,
    /// Sampling-based baselines (Cluster-GCN, GraphSAINT): one randomly
    /// chosen batch per iteration.
    Rotate,
}

/// A prepared training run over a set of partitions.
pub struct Run<B: Backend> {
    workers: Vec<B::Worker>,
    meta: Vec<WorkerMeta>,
    pub model: ModelConfig,
    /// Global Σ tmask·dar — the DAR-normalizing constant (≈ |V_train|).
    pub total_train_weight: f64,
    pub num_partitions: usize,
    pub mode: RunMode,
}

impl<B: Backend> Run<B> {
    /// Assemble a run from workers prepared outside the engine — the
    /// multi-process runtime: workers live in other processes, tensorize
    /// their own shards, and report their [`WorkerMeta`] over the wire.
    /// `meta` must be in worker (rank) order; the total train weight folds
    /// left-to-right over it, matching `prepare_partitions`' accumulation
    /// order so the loss normalization is bit-identical.
    pub fn from_workers(
        workers: Vec<B::Worker>,
        meta: Vec<WorkerMeta>,
        model: ModelConfig,
        mode: RunMode,
    ) -> Run<B> {
        assert_eq!(workers.len(), meta.len(), "one meta per worker");
        let mut total_train_weight = 0.0;
        for m in &meta {
            total_train_weight += m.local_train_weight;
        }
        let num_partitions = workers.len();
        Run { workers, meta, model, total_train_weight, num_partitions, mode }
    }

    /// The prepared workers, in worker order (the dist coordinator uses
    /// this to send shutdown frames after training).
    pub fn workers(&self) -> &[B::Worker] {
        &self.workers
    }
}

/// The engine: Algorithm 1 over any [`Backend`]. `kind` selects the GNN
/// architecture the engine prepares and trains (the loop itself is
/// model-agnostic — only the backend's `train_step` and the parameter
/// layout dispatch on it).
pub struct TrainEngine<B: Backend> {
    pub backend: B,
    pub kind: ModelKind,
}

/// Model config implied by a dataset's recipe (GraphSAGE, the default
/// architecture).
pub fn model_config(ds: &Dataset) -> ModelConfig {
    model_config_for(ds, ModelKind::Sage)
}

/// Model config implied by a dataset's recipe, for an explicit
/// architecture kind.
pub fn model_config_for(ds: &Dataset, kind: ModelKind) -> ModelConfig {
    ModelConfig {
        kind,
        layers: ds.layers,
        feat_dim: ds.data.dim,
        hidden: ds.hidden,
        classes: ds.data.num_classes,
    }
}

/// The RNG stream worker `i` uses to generate its DropEdge-K mask bank.
/// This is THE definition of that stream: `prepare_partitions` draws from
/// it in-process, and the remote worker role re-derives it from
/// `(seed, rank)` alone — both sides must agree bit-for-bit for the
/// cross-process determinism contract to hold.
pub fn worker_mask_rng(seed: u64, worker: usize) -> Rng {
    Rng::new(seed ^ 0xD20B).fork(worker as u64)
}

impl TrainEngine<CpuBackend> {
    /// The native CPU engine (default features, no XLA toolchain needed),
    /// training the default GraphSAGE architecture.
    pub fn native() -> TrainEngine<CpuBackend> {
        TrainEngine::native_model(ModelKind::Sage)
    }

    /// The native CPU engine for an explicit architecture
    /// (`cofree train --model sage|gcn|gin`).
    pub fn native_model(kind: ModelKind) -> TrainEngine<CpuBackend> {
        TrainEngine { backend: CpuBackend::new(), kind }
    }

    /// The native CPU engine at an explicit precision tier
    /// (`cofree train --precision f32|bf16`). Master weights, the
    /// optimizer and eval stay f32; only worker step compute drops.
    pub fn native_model_prec(kind: ModelKind, precision: Precision) -> TrainEngine<CpuBackend> {
        TrainEngine { backend: CpuBackend::with_precision(precision), kind }
    }
}

impl<B: Backend> TrainEngine<B> {
    fn make_worker(
        &mut self,
        model: &ModelConfig,
        batch: TrainBatch,
        dropedge: Option<(usize, f64)>,
        rng: &mut Rng,
    ) -> Result<(B::Worker, WorkerMeta)> {
        let meta = WorkerMeta {
            local_train_weight: batch.local_train_weight,
            tmask_sum: batch.tmask_sum(),
            num_masks: dropedge.map(|(k, _)| k).unwrap_or(0),
        };
        let worker = self.backend.prepare_worker(model, batch, dropedge, rng)?;
        Ok((worker, meta))
    }

    /// Prepare a communication-free run over a vertex cut (Algorithm 1
    /// lines 1–5).
    pub fn prepare_partitions(
        &mut self,
        ds: &Dataset,
        vc: &VertexCut,
        reweighting: Reweighting,
        dropedge: Option<(usize, f64)>,
        seed: u64,
    ) -> Result<Run<B>> {
        let model = model_config_for(ds, self.kind);
        let weights = dar_weights(&ds.graph, vc, reweighting);
        let mut workers = Vec::with_capacity(vc.parts.len());
        let mut meta = Vec::with_capacity(vc.parts.len());
        let mut total_train_weight = 0.0;
        for (i, part) in vc.parts.iter().enumerate() {
            // Smallest shape bucket that fits this partition (the PJRT
            // backend answers from its artifact registry; the native backend
            // rounds to the quantum ladder), then tensorize directly at the
            // padded shape.
            let (n_pad, e_pad) = self.backend.bucket(
                &model,
                ArtifactKind::Train,
                part.num_nodes(),
                2 * part.num_edges(),
            )?;
            let batch = tensorize_partition(part, &ds.data, &weights[i], n_pad, e_pad)
                .with_context(|| format!("tensorizing partition {i}"))?;
            total_train_weight += batch.local_train_weight;
            let (w, m) = self.make_worker(&model, batch, dropedge, &mut worker_mask_rng(seed, i))?;
            workers.push(w);
            meta.push(m);
        }
        Ok(Run {
            workers,
            meta,
            model,
            total_train_weight,
            num_partitions: vc.parts.len(),
            mode: RunMode::AllParts,
        })
    }

    /// Prepare a run from explicit pre-tensorized batches (used by the
    /// sampling-based baselines and the edge-cut ablation).
    pub fn prepare_batches(
        &mut self,
        model: &ModelConfig,
        batches: Vec<TrainBatch>,
        mode: RunMode,
        seed: u64,
    ) -> Result<Run<B>> {
        let rng = Rng::new(seed ^ 0xBA7C);
        let mut workers = Vec::with_capacity(batches.len());
        let mut meta = Vec::with_capacity(batches.len());
        let mut total_train_weight = 0.0;
        let n = batches.len();
        for (i, batch) in batches.into_iter().enumerate() {
            total_train_weight += batch.local_train_weight;
            let (w, m) = self.make_worker(model, batch, None, &mut rng.fork(i as u64))?;
            workers.push(w);
            meta.push(m);
        }
        Ok(Run { workers, meta, model: *model, total_train_weight, num_partitions: n, mode })
    }

    /// Prepare a full-graph (single-partition) run — the Figure 4 baseline.
    pub fn prepare_full(
        &mut self,
        ds: &Dataset,
        dropedge: Option<(usize, f64)>,
        seed: u64,
    ) -> Result<Run<B>> {
        let model = model_config_for(ds, self.kind);
        let (n, m) = (ds.graph.num_nodes(), ds.graph.num_edges());
        let (n_pad, e_pad) = self.backend.bucket(&model, ArtifactKind::Train, n, 2 * m)?;
        let batch = tensorize_full_train(&ds.graph, &ds.data, n_pad, e_pad)?;
        let total_train_weight = batch.local_train_weight;
        let mut rng = Rng::new(seed ^ 0xF011);
        let (worker, wm) = self.make_worker(&model, batch, dropedge, &mut rng)?;
        Ok(Run {
            workers: vec![worker],
            meta: vec![wm],
            model,
            total_train_weight,
            num_partitions: 1,
            mode: RunMode::AllParts,
        })
    }

    /// Prepare full-graph evaluation (val/test accuracy for the tables).
    pub fn prepare_eval(&mut self, ds: &Dataset) -> Result<B::Eval> {
        let model = model_config_for(ds, self.kind);
        let (n, m) = (ds.graph.num_nodes(), ds.graph.num_edges());
        let (n_pad, e_pad) = self.backend.bucket(&model, ArtifactKind::Eval, n, 2 * m)?;
        let batch = tensorize_full_eval(&ds.graph, &ds.data, n_pad, e_pad)?;
        self.backend.prepare_eval(&model, batch)
    }

    /// Evaluate accuracy on a split (0 train, 1 val, 2 test).
    pub fn evaluate(&self, eval: &B::Eval, params: &ParamSet, split: usize) -> Result<f64> {
        self.backend.evaluate(eval, params, split)
    }

    /// Run Algorithm 1 for `cfg.epochs` iterations.
    pub fn train(
        &mut self,
        run: &mut Run<B>,
        eval: Option<&B::Eval>,
        cfg: &TrainConfig,
    ) -> Result<(History, ParamSet, PhaseTimer)> {
        let (history, ck, timer) = self.train_resumable(run, eval, cfg, None)?;
        Ok((history, ck.params, timer))
    }

    /// Run Algorithm 1, optionally resuming from a [`TrainCheckpoint`].
    ///
    /// `cfg.epochs` is the TOTAL trajectory length: resuming a checkpoint
    /// with `epochs_done = k` trains the remaining `cfg.epochs - k` epochs.
    /// For the already-completed epochs the loop replays only the epoch-
    /// level RNG draws (Rotate selection, DropEdge mask picks) so every
    /// stream is positioned exactly where the uninterrupted run would have
    /// it — the save→load→continue trajectory is bit-identical to a
    /// straight run of the same seed and total length. Returns the history
    /// of the epochs actually executed plus the end-of-run checkpoint.
    pub fn train_resumable(
        &mut self,
        run: &mut Run<B>,
        eval: Option<&B::Eval>,
        cfg: &TrainConfig,
        resume: Option<TrainCheckpoint>,
    ) -> Result<(History, TrainCheckpoint, PhaseTimer)> {
        let rng = Rng::new(cfg.seed ^ 0x7247);
        let mut opt: Box<dyn Optimizer> = if cfg.use_adam {
            Box::new(Adam::new(cfg.lr))
        } else {
            Box::new(Sgd { lr: cfg.lr })
        };
        let mut start_epoch = 0usize;
        let mut params = match resume {
            None => ParamSet::init_glorot(&run.model, &mut rng.fork(1)),
            Some(ck) => {
                ensure!(
                    ck.model == run.model,
                    "checkpoint model {:?} does not match run model {:?}",
                    ck.model,
                    run.model
                );
                ensure!(
                    ck.epochs_done <= cfg.epochs,
                    "checkpoint has {} epochs done but the run is only {} epochs long",
                    ck.epochs_done,
                    cfg.epochs
                );
                opt.import_state(ck.opt)
                    .context("restoring optimizer state from checkpoint")?;
                start_epoch = ck.epochs_done;
                ck.params
            }
        };
        let mut acc = GradAccumulator::new();
        let mut history = History::default();
        let mut timer = PhaseTimer::new();
        let scale = if run.total_train_weight > 0.0 {
            (1.0 / run.total_train_weight) as f32
        } else {
            1.0
        };
        let mut mask_rng = rng.fork(2);
        let mut rotate_rng = rng.fork(3);
        // Epoch-level scratch, allocated once and reused every iteration:
        // the worker selection, the pre-drawn mask picks, and the backend's
        // output slots (whose `TrainOut` gradient tensors persist across
        // epochs). Together with each worker's `ModelWorkspace` arena this
        // makes the steady-state epoch allocation-free — asserted by
        // `tests/alloc_steady.rs` under a counting global allocator.
        let mut selected: Vec<usize> = Vec::with_capacity(run.workers.len());
        let mut picks: Vec<Option<usize>> = Vec::with_capacity(run.workers.len());
        let mut outs: Vec<(TrainOut, f64)> = Vec::new();
        ensure!(
            cfg.checkpoint_every == 0 || cfg.checkpoint_path.is_some(),
            "checkpoint_every = {} but no checkpoint path is set",
            cfg.checkpoint_every
        );
        let mut ck_writer = match (&cfg.checkpoint_path, cfg.checkpoint_every) {
            (Some(path), every) if every > 0 => Some(AsyncCheckpointer::spawn(path.clone())),
            _ => None,
        };
        let mut ledger = match &cfg.metrics_out {
            Some(path) => Some(
                crate::obs::Ledger::create(path)
                    .with_context(|| format!("creating run ledger {}", path.display()))?,
            ),
            None => None,
        };
        // Metric handles resolved once, before the loop: registry lookups
        // take a mutex, but updates through the handles are pure atomics,
        // so the steady-state epoch stays allocation- and lock-free.
        let m_epochs = crate::obs::metrics::counter("train.epochs");
        let m_steps = crate::obs::metrics::counter("train.steps");
        let m_epoch_s = crate::obs::metrics::histogram("train.epoch_seconds", EPOCH_SECONDS_BOUNDS);
        history.epochs.reserve(cfg.epochs.saturating_sub(start_epoch));
        for epoch in 0..cfg.epochs {
            // Rotate mode: one random batch this epoch; AllParts: everyone.
            selected.clear();
            match run.mode {
                RunMode::AllParts => selected.extend(0..run.workers.len()),
                RunMode::Rotate => selected.push(rotate_rng.below(run.workers.len())),
            }
            // Pre-draw DropEdge mask picks in worker order so the RNG stream
            // (and therefore the whole trajectory) does not depend on how
            // the backend schedules the workers.
            picks.clear();
            picks.extend(selected.iter().map(|&wi| {
                let nm = run.meta[wi].num_masks;
                if nm > 0 {
                    Some(mask_rng.below(nm))
                } else {
                    None
                }
            }));
            if epoch < start_epoch {
                // Resumed epoch: the draws above already advanced the RNG
                // streams; the compute itself is in the checkpoint.
                continue;
            }
            acc.reset();
            let t0 = Instant::now();
            self.backend.run_workers(&run.workers, &selected, &picks, &params, &mut outs)?;
            let execute_s = t0.elapsed().as_secs_f64();
            timer.add_span("execute", t0);
            // The only cross-worker traffic: sum gradients, in worker order.
            let t1 = Instant::now();
            let mut max_worker = 0f64;
            let mut epoch_weight = 0.0f64;
            for ((out, dt), &wi) in outs.iter().zip(&selected) {
                max_worker = max_worker.max(*dt);
                epoch_weight += run.meta[wi].local_train_weight;
                acc.add(out);
            }
            let allreduce_s = t1.elapsed().as_secs_f64();
            timer.add_span("allreduce", t1);
            let t2 = Instant::now();
            let epoch_scale = match run.mode {
                RunMode::AllParts => scale,
                // Rotate: normalize by the chosen batch's own weight sum.
                RunMode::Rotate => {
                    if epoch_weight > 0.0 {
                        (1.0 / epoch_weight) as f32
                    } else {
                        1.0
                    }
                }
            };
            opt.step(&mut params.data, acc.grads(), epoch_scale);
            let optim_s = t2.elapsed().as_secs_f64();
            timer.add_span("optim", t2);
            if let Some(ck) = ck_writer.as_mut() {
                // Snapshot the *post-step* state every N epochs (skipping
                // the final epoch — the run's own checkpoint covers it).
                // The offer copies into a pre-owned buffer and returns;
                // serialization and I/O happen on the writer thread.
                if (epoch + 1) % cfg.checkpoint_every == 0 && epoch + 1 < cfg.epochs {
                    ck.offer(epoch + 1, &run.model, &params, opt.as_ref());
                }
            }

            let do_eval = eval.is_some()
                && (epoch + 1 == cfg.epochs
                    || (cfg.eval_every > 0 && epoch % cfg.eval_every == 0));
            let (val_acc, test_acc) = if do_eval {
                // Single call: backends that can score both splits from one
                // forward (the native backend) do so.
                self.backend.evaluate_val_test(eval.unwrap(), &params)?
            } else {
                (f64::NAN, f64::NAN)
            };
            let norm = match run.mode {
                RunMode::AllParts => run.total_train_weight,
                RunMode::Rotate => epoch_weight,
            };
            let train_loss = acc.loss_sum / norm.max(1e-9);
            let train_acc = acc.correct
                / selected
                    .iter()
                    .map(|&wi| run.meta[wi].tmask_sum)
                    .sum::<f64>()
                    .max(1e-9);
            let stats = EpochStats {
                epoch,
                train_loss,
                train_acc,
                val_acc,
                test_acc,
                iter_time: max_worker + cfg.allreduce_seconds + optim_s,
                max_worker_time: max_worker,
            };
            if cfg.log_every > 0 && epoch % cfg.log_every == 0 {
                crate::log_info!(
                    "epoch {epoch:4} loss={train_loss:.4} train_acc={train_acc:.3} val={val_acc:.3} test={test_acc:.3} iter={:.1}ms",
                    stats.iter_time * 1e3
                );
            }
            m_epochs.inc();
            m_steps.add(selected.len() as u64);
            m_epoch_s.observe(stats.iter_time);
            crate::obs::trace::record_since("epoch", t0);
            if let Some(l) = ledger.as_mut() {
                l.write_epoch(
                    &stats,
                    &[("execute", execute_s), ("allreduce", allreduce_s), ("optim", optim_s)],
                )?;
            }
            history.push(stats);
        }
        if let Some(ck) = ck_writer.take() {
            let (written, skipped) = ck.finish().context("flushing periodic checkpoints")?;
            crate::log_info!("periodic checkpoints: {written} written, {skipped} skipped");
        }
        let checkpoint = TrainCheckpoint {
            epochs_done: cfg.epochs,
            model: run.model,
            params,
            opt: opt.export_state(),
        };
        Ok((history, checkpoint, timer))
    }
}

// ---------------------------------------------------------------------------
// The PJRT backend (`--features xla`): AOT-compiled artifacts executed
// through the PJRT C API.
// ---------------------------------------------------------------------------

/// One worker = one partition's state: device-resident batch + executor.
#[cfg(feature = "xla")]
pub struct XlaWorker {
    batch: TrainBatch,
    /// Device buffers in tensor order (emask slot swapped per iteration).
    device: Vec<xla::PjRtBuffer>,
    /// DropEdge masks, pre-uploaded.
    mask_buffers: Vec<xla::PjRtBuffer>,
    executor: Rc<Executor>,
}

/// A prepared full-graph evaluation setup on the device.
#[cfg(feature = "xla")]
pub struct EvalSetup {
    batch: EvalBatch,
    device: Vec<xla::PjRtBuffer>,
    mask_buffers: [xla::PjRtBuffer; 3],
    executor: Rc<Executor>,
}

/// PJRT client + artifact registry + executable cache.
#[cfg(feature = "xla")]
pub struct XlaBackend {
    pub rt: RuntimeClient,
    pub registry: Registry,
    cache: HashMap<String, Rc<Executor>>,
}

/// The engine over the PJRT backend (the pre-refactor `TrainEngine`).
#[cfg(feature = "xla")]
pub type XlaEngine = TrainEngine<XlaBackend>;

#[cfg(feature = "xla")]
impl TrainEngine<XlaBackend> {
    pub fn new(artifacts_dir: &Path) -> Result<TrainEngine<XlaBackend>> {
        Ok(TrainEngine {
            backend: XlaBackend {
                rt: RuntimeClient::cpu()?,
                registry: Registry::load(artifacts_dir)?,
                cache: HashMap::new(),
            },
            // The AOT artifacts lower the GraphSAGE step only; other model
            // kinds run on the native backend.
            kind: ModelKind::Sage,
        })
    }
}

#[cfg(feature = "xla")]
impl XlaBackend {
    /// Compile-or-fetch an executor for an artifact. The registry lookup
    /// stays borrowed (the pre-PR code cloned the whole `ArtifactSpec` —
    /// name, model, paths — on every call just to appease the borrow
    /// checker); the spec is only cloned once, inside `Executor::compile`,
    /// on the cache-miss path, and cache hits hand out an `Rc` handle.
    fn executor(
        &mut self,
        model: &ModelConfig,
        kind: ArtifactKind,
        n: usize,
        e: usize,
    ) -> Result<Rc<Executor>> {
        let spec = self.registry.find(model, kind, n, e)?;
        if let Some(exe) = self.cache.get(&spec.name) {
            return Ok(Rc::clone(exe));
        }
        let exe = Rc::new(Executor::compile(&self.rt, spec)?);
        self.cache.insert(spec.name.clone(), Rc::clone(&exe));
        Ok(exe)
    }
}

#[cfg(feature = "xla")]
impl Backend for XlaBackend {
    type Worker = XlaWorker;
    type Eval = EvalSetup;

    fn name(&self) -> &'static str {
        "xla"
    }

    fn bucket(
        &mut self,
        model: &ModelConfig,
        kind: ArtifactKind,
        n_need: usize,
        e_need: usize,
    ) -> Result<(usize, usize)> {
        let spec = self.registry.find(model, kind, n_need, e_need)?;
        Ok((spec.n_pad, spec.e_pad))
    }

    fn prepare_worker(
        &mut self,
        model: &ModelConfig,
        batch: TrainBatch,
        dropedge: Option<(usize, f64)>,
        rng: &mut Rng,
    ) -> Result<XlaWorker> {
        let executor = self.executor(model, ArtifactKind::Train, batch.n_pad, batch.e_pad)?;
        let device = executor.upload_data(&self.rt, &batch.tensors)?;
        let mask_buffers = match dropedge {
            None => Vec::new(),
            Some((k, ratio)) => {
                let bank = MaskBank::generate(&batch, k, ratio, rng);
                bank.masks
                    .iter()
                    .map(|m| m.to_device(&self.rt))
                    .collect::<Result<Vec<_>>>()?
            }
        };
        Ok(XlaWorker { batch, device, mask_buffers, executor })
    }

    fn prepare_eval(&mut self, model: &ModelConfig, batch: EvalBatch) -> Result<EvalSetup> {
        let executor = self.executor(model, ArtifactKind::Eval, batch.n_pad, batch.e_pad)?;
        let device = executor.upload_data(&self.rt, &batch.tensors)?;
        let mask_buffers = [
            batch.masks[0].to_device(&self.rt)?,
            batch.masks[1].to_device(&self.rt)?,
            batch.masks[2].to_device(&self.rt)?,
        ];
        Ok(EvalSetup { batch, device, mask_buffers, executor })
    }

    fn run_workers(
        &self,
        workers: &[XlaWorker],
        selected: &[usize],
        picks: &[Option<usize>],
        params: &ParamSet,
        outs: &mut Vec<(TrainOut, f64)>,
    ) -> Result<()> {
        // One device: workers execute sequentially; each step is timed
        // individually so the engine can report max_i(compute_i). (The PJRT
        // result tuples are freshly allocated by the runtime either way, so
        // this backend refills `outs` rather than recycling its slots.)
        outs.clear();
        outs.reserve(selected.len());
        for (&wi, pick) in selected.iter().zip(picks) {
            let w = &workers[wi];
            let t0 = Instant::now();
            let out = {
                let mut refs: Vec<&xla::PjRtBuffer> = w.device.iter().collect();
                if let Some(k) = pick {
                    // DropEdge-K: swap the emask device buffer (zero host
                    // work).
                    refs[TrainBatch::EMASK_IDX] = &w.mask_buffers[*k];
                }
                w.executor.execute_train(&self.rt, params, &refs)?
            };
            let _ = &w.batch; // keep host copy alive alongside device buffers
            outs.push((out, t0.elapsed().as_secs_f64()));
        }
        Ok(())
    }

    fn evaluate(&self, eval: &EvalSetup, params: &ParamSet, split: usize) -> Result<f64> {
        let mut refs: Vec<&xla::PjRtBuffer> = eval.device.iter().collect();
        refs.push(&eval.mask_buffers[split]);
        let out = eval.executor.execute_eval(&self.rt, params, &refs)?;
        let _ = &eval.batch; // keep host copy alive alongside device buffers
        Ok(out.accuracy())
    }
}
