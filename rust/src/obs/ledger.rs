//! The structured run ledger: `cofree train --metrics-out metrics.jsonl`.
//!
//! One JSON object per line (JSONL), so a crashed run still leaves a
//! parseable prefix — every epoch record is flushed and fsynced the moment
//! it is written, and each line is self-describing via its `"record"` key:
//!
//! * `{"record": "epoch", ...}` — one per trained epoch: loss, accuracies
//!   (null on non-eval epochs), epoch wall-clock, max per-worker compute,
//!   and the coordinator's per-phase seconds for that epoch.
//! * `{"record": "summary", ...}` — appended once after training: best
//!   val/test, cumulative phase totals, the metrics-registry snapshot,
//!   and (proc transport) [`DistStats::to_json`] with its per-rank phase
//!   breakdowns.
//!
//! The epoch records are written by the engine (both transports share the
//! same loop); the summary is appended by the CLI after training returns,
//! because only the CLI sees the [`DistStats`] the proc coordinator folds.
//! This is the artifact `bench_*` harnesses and future serving/ABC
//! comparisons consume — a schema table lives in DESIGN.md §7.

use crate::dist::DistStats;
use crate::train::metrics::{EpochStats, History};
use crate::util::binio;
use anyhow::{Context, Result};
use std::fmt::Write as _;
use std::fs::{File, OpenOptions};
use std::io::Write as _;
use std::path::{Path, PathBuf};

/// Emit an f64 as JSON: finite values verbatim, NaN/inf as `null` (JSON
/// has no non-finite literals; val/test accuracy are NaN on non-eval
/// epochs by contract).
fn push_num(out: &mut String, v: f64) {
    if v.is_finite() {
        let _ = write!(out, "{v}");
    } else {
        out.push_str("null");
    }
}

fn push_phases(out: &mut String, phases: &[(&str, f64)]) {
    out.push('{');
    for (i, (name, secs)) in phases.iter().enumerate() {
        if i > 0 {
            out.push_str(", ");
        }
        let _ = write!(out, "\"{name}_s\": ");
        push_num(out, *secs);
    }
    out.push('}');
}

/// The per-epoch half of the ledger, owned by the training loop. Created
/// with truncate semantics (a re-run replaces the previous ledger), parent
/// directory fsynced so the file's existence is durable before the first
/// record lands.
pub struct Ledger {
    f: File,
    path: PathBuf,
    line: String,
}

impl Ledger {
    pub fn create(path: &Path) -> Result<Ledger> {
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)
                    .with_context(|| format!("creating ledger directory {}", parent.display()))?;
            }
        }
        let f = File::create(path)
            .with_context(|| format!("creating run ledger {}", path.display()))?;
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                binio::sync_dir(parent)?;
            }
        }
        Ok(Ledger { f, path: path.to_path_buf(), line: String::with_capacity(512) })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Append one epoch record and make it durable (flush + fdatasync):
    /// a run that dies in epoch N leaves records 0..N intact on disk.
    pub fn write_epoch(&mut self, s: &EpochStats, phases: &[(&str, f64)]) -> Result<()> {
        self.line.clear();
        let _ =
            write!(self.line, "{{\"record\": \"epoch\", \"epoch\": {}, \"train_loss\": ", s.epoch);
        push_num(&mut self.line, s.train_loss);
        self.line.push_str(", \"train_acc\": ");
        push_num(&mut self.line, s.train_acc);
        self.line.push_str(", \"val_acc\": ");
        push_num(&mut self.line, s.val_acc);
        self.line.push_str(", \"test_acc\": ");
        push_num(&mut self.line, s.test_acc);
        self.line.push_str(", \"epoch_s\": ");
        push_num(&mut self.line, s.iter_time);
        self.line.push_str(", \"max_worker_s\": ");
        push_num(&mut self.line, s.max_worker_time);
        self.line.push_str(", \"phases\": ");
        push_phases(&mut self.line, phases);
        self.line.push_str("}\n");
        self.f
            .write_all(self.line.as_bytes())
            .and_then(|()| self.f.flush())
            .and_then(|()| self.f.sync_data())
            .with_context(|| format!("appending epoch record to {}", self.path.display()))?;
        Ok(())
    }
}

/// Append the final run-summary record: best accuracies, cumulative phase
/// totals, wire/fleet stats (proc transport), and the metrics-registry
/// snapshot. Fully fsynced (file + parent directory) before returning.
pub fn append_summary(
    path: &Path,
    history: &History,
    phases: &[(&str, f64)],
    dist: Option<&DistStats>,
) -> Result<()> {
    let (best_val, test_at_best) = history.best();
    let total_s: f64 = history.epochs.iter().map(|e| e.iter_time).sum();
    let mut line = String::with_capacity(1024);
    let _ = write!(
        line,
        "{{\"record\": \"summary\", \"epochs\": {}, \"best_val_acc\": ",
        history.epochs.len()
    );
    push_num(&mut line, best_val);
    line.push_str(", \"test_at_best\": ");
    push_num(&mut line, test_at_best);
    line.push_str(", \"total_s\": ");
    push_num(&mut line, total_s);
    line.push_str(", \"phases\": ");
    push_phases(&mut line, phases);
    line.push_str(", \"dist\": ");
    match dist {
        Some(stats) => line.push_str(&stats.to_json()),
        None => line.push_str("null"),
    }
    line.push_str(", \"metrics\": ");
    line.push_str(&super::metrics::snapshot_json());
    line.push_str("}\n");
    let mut f = OpenOptions::new()
        .append(true)
        .create(true)
        .open(path)
        .with_context(|| format!("opening run ledger {} for the summary", path.display()))?;
    f.write_all(line.as_bytes())
        .and_then(|()| f.flush())
        .and_then(|()| f.sync_all())
        .with_context(|| format!("appending summary record to {}", path.display()))?;
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            binio::sync_dir(parent)?;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    fn stats(epoch: usize, val: f64) -> EpochStats {
        EpochStats {
            epoch,
            train_loss: 0.5,
            train_acc: 0.75,
            val_acc: val,
            test_acc: val,
            iter_time: 0.01,
            max_worker_time: 0.008,
        }
    }

    #[test]
    fn ledger_lines_are_valid_jsonl_with_nan_as_null() {
        let path = std::env::temp_dir()
            .join(format!("cofree_ledger_test_{}.jsonl", std::process::id()));
        let _ = std::fs::remove_file(&path);
        {
            let mut l = Ledger::create(&path).unwrap();
            l.write_epoch(&stats(0, f64::NAN), &[("execute", 0.008), ("optim", 0.001)]).unwrap();
            l.write_epoch(&stats(1, 0.6), &[("execute", 0.009), ("optim", 0.001)]).unwrap();
        }
        let mut h = History::default();
        h.push(stats(0, f64::NAN));
        h.push(stats(1, 0.6));
        append_summary(&path, &h, &[("execute", 0.017)], None).unwrap();

        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 3);
        let r0 = json::parse(lines[0].as_bytes()).expect("epoch 0 line parses");
        assert_eq!(r0.get("record").and_then(|r| r.as_str()), Some("epoch"));
        assert!(matches!(r0.get("val_acc"), Some(&json::Json::Null)), "NaN must render as null");
        assert_eq!(
            r0.get("phases").and_then(|p| p.get("execute_s")).and_then(|v| v.as_f64()),
            Some(0.008)
        );
        let r1 = json::parse(lines[1].as_bytes()).unwrap();
        assert_eq!(r1.get("val_acc").and_then(|v| v.as_f64()), Some(0.6));
        let s = json::parse(lines[2].as_bytes()).expect("summary line parses");
        assert_eq!(s.get("record").and_then(|r| r.as_str()), Some("summary"));
        assert_eq!(s.get("epochs").and_then(|v| v.as_u64()), Some(2));
        assert_eq!(s.get("best_val_acc").and_then(|v| v.as_f64()), Some(0.6));
        assert!(matches!(s.get("dist"), Some(&json::Json::Null)));
        assert!(s.get("metrics").and_then(|m| m.get("counters")).is_some());
        std::fs::remove_file(&path).unwrap();
    }
}
