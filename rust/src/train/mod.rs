//! The CoFree-GNN training engine (Layer 3).
//!
//! Implements Algorithm 1 of the paper: vertex-cut partitions are
//! tensorized into padded shape buckets, each worker executes the
//! AOT-compiled `train_step` on its own partition with **zero embedding
//! communication**, the leader sums the DAR-weighted gradients (the only
//! cross-worker traffic) and applies the optimizer.

pub mod allreduce;
pub mod bucket;
pub mod dropedge;
pub mod engine;
pub mod metrics;
pub mod optimizer;
pub mod reference;
pub mod sampling;
pub mod tensorize;

pub use bucket::bucket_shapes;
pub use dropedge::MaskBank;
pub use engine::TrainConfig;
#[cfg(feature = "xla")]
pub use engine::TrainEngine;
pub use metrics::{EpochStats, History};
pub use optimizer::{Adam, Optimizer, Sgd};
pub use tensorize::{tensorize_full_eval, tensorize_full_train, tensorize_partition, EvalBatch, TrainBatch};
