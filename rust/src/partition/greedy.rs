//! PowerGraph's greedy streaming vertex cut (Gonzalez et al., OSDI'12) —
//! the algorithm from the paper the Vertex Cut idea is taken from ([8]).
//!
//! Edges arrive in stream order; each is placed by the classic four-case
//! rule over the sets `A(v)` of partitions already hosting `v`:
//!
//! 1. `A(u) ∩ A(v) ≠ ∅` → least-loaded common partition,
//! 2. both non-empty but disjoint → least-loaded partition hosting the
//!    endpoint with more remaining edges (we approximate "remaining" by
//!    total degree, as the original does with unplaced-edge counts),
//! 3. exactly one non-empty → least-loaded partition hosting that endpoint,
//! 4. both new → globally least-loaded partition.
//!
//! Two stream orders are offered:
//!
//! * [`PowerGraphGreedy`] (`greedy`) shuffles the canonical edge list with
//!   the run's RNG — the historical default, closest to the randomized
//!   stream the original paper analyzes;
//! * [`SequentialGreedy`] (`greedy-seq`) consumes the canonical
//!   lexicographic order and draws nothing from the RNG, which makes it a
//!   pure function of the *edge stream* alone. That is the variant the
//!   out-of-core pipeline ([`crate::ingest`]) can run over a merged edge
//!   stream it never holds in memory, with bitwise-identical output to
//!   this in-memory oracle.
//!
//! Both share one per-edge core, [`GreedyState`]: for `p ≤ 64` the host
//! sets are single `u64` bitsets intersected in place, so the per-edge
//! step performs **no heap allocation**; `p > 64` falls back to sorted
//! small-vecs. All ties resolve to the lowest part id, making either
//! assignment deterministic across runs and rayon thread counts.

use super::VertexCutAlgorithm;
use crate::graph::Graph;
use crate::util::rng::Rng;

/// Greedy streaming vertex cut over a shuffled edge stream.
pub struct PowerGraphGreedy;

/// Greedy streaming vertex cut over the canonical (lexicographic) edge
/// stream. Draws nothing from the RNG: the assignment is a pure function
/// of the deduped canonical edge list, the degree table and `p` — the
/// property the streaming ingest tier relies on for bitwise parity.
pub struct SequentialGreedy;

/// Least-loaded partition among the set bits of `mask`; ties go to the
/// lowest part id (the first-minimum rule of `Iterator::min_by_key`).
#[inline]
fn least_loaded_bit(mut mask: u64, load: &[usize]) -> u32 {
    debug_assert!(mask != 0);
    let mut best = mask.trailing_zeros();
    mask &= mask - 1;
    while mask != 0 {
        let c = mask.trailing_zeros();
        if load[c as usize] < load[best as usize] {
            best = c;
        }
        mask &= mask - 1;
    }
    best
}

/// Least-loaded partition overall; ties go to the lowest part id.
#[inline]
fn least_loaded_all(p: usize, load: &[usize]) -> u32 {
    (0..p as u32).min_by_key(|&c| load[c as usize]).unwrap()
}

/// Case 2 (both host sets non-empty, disjoint): favor the endpoint with
/// more remaining edges, approximated by total degree. Degree ties go to
/// the canonical lower endpoint `u` — an explicit, deterministic rule, not
/// an artifact of set representation.
#[inline]
fn case2_pick(du: u32, dv: u32, hosts_u: u64, hosts_v: u64) -> u64 {
    if du >= dv {
        hosts_u
    } else {
        hosts_v
    }
}

/// Per-vertex host sets: one `u64` bitset per node when `p ≤ 64`, sorted
/// small-vecs otherwise.
enum HostSets {
    Bits(Vec<u64>),
    Vecs(Vec<Vec<u32>>),
}

/// The incremental four-case greedy placement core, shared verbatim by the
/// in-memory algorithms above and by the out-of-core streaming assigner —
/// one implementation, so their parity is by construction, not by test
/// luck. State is O(V): the per-vertex host sets plus `p` load counters.
pub struct GreedyState {
    p: usize,
    load: Vec<usize>,
    hosts: HostSets,
}

impl GreedyState {
    /// Fresh state for `n` nodes and `p` partitions.
    pub fn new(n: usize, p: usize) -> GreedyState {
        let hosts = if p <= 64 {
            HostSets::Bits(vec![0u64; n])
        } else {
            HostSets::Vecs(vec![Vec::new(); n])
        };
        GreedyState { p, load: vec![0usize; p], hosts }
    }

    /// Place one edge `(u, v)` with endpoint degrees `(du, dv)`; returns
    /// the chosen part and updates the host sets and load counters.
    #[inline]
    pub fn place(&mut self, u: u32, v: u32, du: u32, dv: u32) -> u32 {
        let choice = match &mut self.hosts {
            HostSets::Bits(abits) => {
                // Bitset path: A(v) is one u64 word; this touches no heap.
                let (bu, bv) = (abits[u as usize], abits[v as usize]);
                let common = bu & bv;
                let choice = if common != 0 {
                    least_loaded_bit(common, &self.load)
                } else if bu != 0 && bv != 0 {
                    let pick = case2_pick(du, dv, bu, bv);
                    least_loaded_bit(pick, &self.load)
                } else if bu != 0 {
                    least_loaded_bit(bu, &self.load)
                } else if bv != 0 {
                    least_loaded_bit(bv, &self.load)
                } else {
                    least_loaded_all(self.p, &self.load)
                };
                let bit = 1u64 << choice;
                abits[u as usize] |= bit;
                abits[v as usize] |= bit;
                choice
            }
            HostSets::Vecs(avec) => {
                // p > 64: sorted small-vec host sets. The selection borrows
                // the sets in place (no per-edge clones or scratch vectors).
                let choice = {
                    let hu = &avec[u as usize];
                    let hv = &avec[v as usize];
                    let common = hu
                        .iter()
                        .copied()
                        .filter(|c| hv.binary_search(c).is_ok())
                        .min_by_key(|&c| self.load[c as usize]);
                    if let Some(c) = common {
                        c
                    } else if !hu.is_empty() && !hv.is_empty() {
                        let pick = if du >= dv { hu } else { hv };
                        *pick.iter().min_by_key(|&&c| self.load[c as usize]).unwrap()
                    } else if !hu.is_empty() {
                        *hu.iter().min_by_key(|&&c| self.load[c as usize]).unwrap()
                    } else if !hv.is_empty() {
                        *hv.iter().min_by_key(|&&c| self.load[c as usize]).unwrap()
                    } else {
                        least_loaded_all(self.p, &self.load)
                    }
                };
                for &node in &[u, v] {
                    let a = &mut avec[node as usize];
                    if let Err(pos) = a.binary_search(&choice) {
                        a.insert(pos, choice);
                    }
                }
                choice
            }
        };
        self.load[choice as usize] += 1;
        choice
    }
}

impl VertexCutAlgorithm for PowerGraphGreedy {
    fn name(&self) -> &'static str {
        "greedy"
    }

    fn assign(&self, g: &Graph, p: usize, rng: &mut Rng) -> Vec<u32> {
        let m = g.num_edges();
        let mut order: Vec<u32> = (0..m as u32).collect();
        rng.shuffle(&mut order);
        // One precomputed degree slice for the whole stream (case-2 rule)
        // instead of per-edge accessor calls.
        let degree = g.degrees();
        let mut state = GreedyState::new(g.num_nodes(), p);
        let mut out = vec![0u32; m];
        for &k in &order {
            let (u, v) = g.edges()[k as usize];
            out[k as usize] = state.place(u, v, degree[u as usize], degree[v as usize]);
        }
        out
    }
}

impl VertexCutAlgorithm for SequentialGreedy {
    fn name(&self) -> &'static str {
        "greedy-seq"
    }

    fn assign(&self, g: &Graph, p: usize, _rng: &mut Rng) -> Vec<u32> {
        let degree = g.degrees();
        let mut state = GreedyState::new(g.num_nodes(), p);
        g.edges()
            .iter()
            .map(|&(u, v)| state.place(u, v, degree[u as usize], degree[v as usize]))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::barabasi_albert;
    use crate::partition::metrics::PartitionMetrics;
    use crate::partition::{random::RandomVertexCut, VertexCut};

    #[test]
    fn beats_random_on_replication() {
        let mut rng = Rng::new(6);
        let g = barabasi_albert(2000, 4, &mut rng);
        let vc_g = VertexCut::create(&g, 8, &PowerGraphGreedy, &mut rng.fork(1));
        let vc_r = VertexCut::create(&g, 8, &RandomVertexCut, &mut rng.fork(2));
        let mg = PartitionMetrics::vertex_cut(&g, &vc_g);
        let mr = PartitionMetrics::vertex_cut(&g, &vc_r);
        assert!(
            mg.replication_factor < mr.replication_factor,
            "greedy {} random {}",
            mg.replication_factor,
            mr.replication_factor
        );
    }

    #[test]
    fn sequential_variant_beats_random_on_replication() {
        let mut rng = Rng::new(6);
        let g = barabasi_albert(2000, 4, &mut rng);
        let vc_g = VertexCut::create(&g, 8, &SequentialGreedy, &mut rng.fork(1));
        let vc_r = VertexCut::create(&g, 8, &RandomVertexCut, &mut rng.fork(2));
        let mg = PartitionMetrics::vertex_cut(&g, &vc_g);
        let mr = PartitionMetrics::vertex_cut(&g, &vc_r);
        assert!(
            mg.replication_factor < mr.replication_factor,
            "greedy-seq {} random {}",
            mg.replication_factor,
            mr.replication_factor
        );
    }

    #[test]
    fn load_is_balanced() {
        let mut rng = Rng::new(7);
        let g = barabasi_albert(1000, 5, &mut rng);
        let vc = VertexCut::create(&g, 7, &PowerGraphGreedy, &mut rng);
        let m = PartitionMetrics::vertex_cut(&g, &vc);
        assert!(m.edge_balance < 1.15, "imbalance {}", m.edge_balance);
    }

    #[test]
    fn many_partitions_vec_path() {
        // p > 64 exercises the non-bitset path, on both stream orders.
        let mut rng = Rng::new(8);
        let g = barabasi_albert(800, 3, &mut rng);
        let vc = VertexCut::create(&g, 100, &PowerGraphGreedy, &mut rng);
        vc.check_invariants(&g).unwrap();
        let vc = VertexCut::create(&g, 100, &SequentialGreedy, &mut rng);
        vc.check_invariants(&g).unwrap();
    }

    #[test]
    fn case2_tie_breaks_to_lower_endpoint() {
        // Higher-degree endpoint wins; equal degrees go to u's hosts.
        assert_eq!(case2_pick(4, 3, 0b01, 0b10), 0b01);
        assert_eq!(case2_pick(2, 3, 0b01, 0b10), 0b10);
        assert_eq!(case2_pick(3, 3, 0b01, 0b10), 0b01);
    }

    /// Regression (satellite): the same seed must yield the same assignment
    /// on every run and under every rayon pool size, on both host-set
    /// representations.
    #[test]
    fn deterministic_across_runs_and_thread_counts() {
        let mut rng = Rng::new(21);
        let g = barabasi_albert(1500, 4, &mut rng);
        for p in [8usize, 80] {
            let a = PowerGraphGreedy.assign(&g, p, &mut Rng::new(5));
            let b = PowerGraphGreedy.assign(&g, p, &mut Rng::new(5));
            assert_eq!(a, b, "p={p}: two runs diverged");
            for threads in [1usize, 2, 8] {
                let pool =
                    rayon::ThreadPoolBuilder::new().num_threads(threads).build().unwrap();
                let c = pool.install(|| PowerGraphGreedy.assign(&g, p, &mut Rng::new(5)));
                assert_eq!(a, c, "p={p} threads={threads}");
            }
        }
    }

    /// `greedy-seq` ignores its RNG entirely: any two seeds agree, and its
    /// assignment equals an incremental [`GreedyState`] replay over the
    /// canonical edge list (the exact loop the streaming assigner runs).
    #[test]
    fn sequential_is_rng_free_and_matches_incremental_replay() {
        let mut rng = Rng::new(22);
        let g = barabasi_albert(1200, 4, &mut rng);
        for p in [1usize, 5, 64, 90] {
            let a = SequentialGreedy.assign(&g, p, &mut Rng::new(1));
            let b = SequentialGreedy.assign(&g, p, &mut Rng::new(999));
            assert_eq!(a, b, "p={p}: greedy-seq consumed RNG state");
            let degree = g.degrees();
            let mut state = GreedyState::new(g.num_nodes(), p);
            let replay: Vec<u32> = g
                .edges()
                .iter()
                .map(|&(u, v)| state.place(u, v, degree[u as usize], degree[v as usize]))
                .collect();
            assert_eq!(a, replay, "p={p}: incremental replay diverged");
        }
    }
}
