"""Pallas kernels vs pure-jnp oracles — the core L1 correctness signal.

Hypothesis sweeps shapes; fixed-seed numpy data keeps runs deterministic.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import matmul as pk
from compile.kernels import ref

DIM = st.integers(min_value=1, max_value=50)


def rand(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape, dtype=np.float32))


@settings(max_examples=25, deadline=None)
@given(m=DIM, k=DIM, n=DIM, seed=st.integers(0, 2**31 - 1))
def test_matmul_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x, w = rand(rng, m, k), rand(rng, k, n)
    got = pk.matmul(x, w)
    want = ref.matmul_ref(x, w)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(m=DIM, k=DIM, n=DIM, seed=st.integers(0, 2**31 - 1))
def test_relu_linear_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x, w, b = rand(rng, m, k), rand(rng, k, n), rand(rng, n)
    got = pk.relu_linear(x, w, b)
    want = ref.relu_linear_ref(x, w, b)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(m=st.integers(2, 24), k=st.integers(2, 24), n=st.integers(2, 24),
       seed=st.integers(0, 2**31 - 1))
def test_matmul_gradients_match_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x, w = rand(rng, m, k), rand(rng, k, n)

    def f_pallas(x, w):
        return (pk.matmul(x, w) ** 2).sum()

    def f_ref(x, w):
        return (ref.matmul_ref(x, w) ** 2).sum()

    gx, gw = jax.grad(f_pallas, argnums=(0, 1))(x, w)
    rx, rw = jax.grad(f_ref, argnums=(0, 1))(x, w)
    np.testing.assert_allclose(gx, rx, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(gw, rw, rtol=1e-3, atol=1e-3)


@settings(max_examples=10, deadline=None)
@given(m=st.integers(2, 24), k=st.integers(2, 24), n=st.integers(2, 24),
       seed=st.integers(0, 2**31 - 1))
def test_relu_linear_gradients_match_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x, w, b = rand(rng, m, k), rand(rng, k, n), rand(rng, n)

    def f_pallas(x, w, b):
        return (pk.relu_linear(x, w, b) * jnp.arange(n)).sum()

    def f_ref(x, w, b):
        return (ref.relu_linear_ref(x, w, b) * jnp.arange(n)).sum()

    gp = jax.grad(f_pallas, argnums=(0, 1, 2))(x, w, b)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(x, w, b)
    for a, b_ in zip(gp, gr):
        np.testing.assert_allclose(a, b_, rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("m,k,n", [(1, 1, 1), (128, 128, 128), (129, 257, 65), (7, 384, 3)])
def test_matmul_block_boundaries(m, k, n):
    """Shapes exactly at / around tile boundaries."""
    rng = np.random.default_rng(0)
    x, w = rand(rng, m, k), rand(rng, k, n)
    np.testing.assert_allclose(pk.matmul(x, w), ref.matmul_ref(x, w), rtol=1e-4, atol=1e-4)


def test_segment_mean_basic():
    vals = jnp.array([[1.0], [2.0], [4.0], [8.0]])
    seg = jnp.array([0, 0, 1, 3])
    w = jnp.array([1.0, 1.0, 1.0, 0.0])  # last edge masked out
    out = ref.segment_mean_ref(vals, seg, w, 4)
    np.testing.assert_allclose(out[0], [1.5])
    np.testing.assert_allclose(out[1], [4.0])
    np.testing.assert_allclose(out[2], [0.0])  # empty segment
    np.testing.assert_allclose(out[3], [0.0])  # fully masked segment


@settings(max_examples=20, deadline=None)
@given(n_nodes=st.integers(1, 40), n_edges=st.integers(0, 200), d=st.integers(1, 8),
       seed=st.integers(0, 2**31 - 1))
def test_segment_mean_properties(n_nodes, n_edges, d, seed):
    rng = np.random.default_rng(seed)
    vals = rand(rng, max(n_edges, 1), d)[:n_edges]
    if n_edges == 0:
        vals = jnp.zeros((0, d), jnp.float32)
    seg = jnp.asarray(rng.integers(0, n_nodes, size=n_edges), dtype=jnp.int32)
    w = jnp.asarray(rng.integers(0, 2, size=n_edges), dtype=jnp.float32)
    out = ref.segment_mean_ref(vals, seg, w, n_nodes)
    assert out.shape == (n_nodes, d)
    # Mean of a 0/1-weighted set lies within the min/max of the kept values.
    arr = np.asarray(out)
    vals_np, seg_np, w_np = np.asarray(vals), np.asarray(seg), np.asarray(w)
    for s in range(n_nodes):
        kept = vals_np[(seg_np == s) & (w_np > 0)]
        if len(kept) == 0:
            np.testing.assert_allclose(arr[s], 0.0, atol=1e-6)
        else:
            assert (arr[s] >= kept.min(axis=0) - 1e-5).all()
            assert (arr[s] <= kept.max(axis=0) + 1e-5).all()


def test_weighted_segment_mean_equals_dropedge_renormalization():
    """DropEdge semantics: masking edges renormalizes the mean over the
    survivors (not over the original degree)."""
    vals = jnp.array([[2.0], [4.0], [6.0]])
    seg = jnp.array([0, 0, 0])
    w_all = jnp.array([1.0, 1.0, 1.0])
    w_drop = jnp.array([1.0, 0.0, 1.0])
    np.testing.assert_allclose(ref.segment_mean_ref(vals, seg, w_all, 1)[0], [4.0])
    np.testing.assert_allclose(ref.segment_mean_ref(vals, seg, w_drop, 1)[0], [4.0])
    w_drop2 = jnp.array([0.0, 1.0, 0.0])
    np.testing.assert_allclose(ref.segment_mean_ref(vals, seg, w_drop2, 1)[0], [4.0])
    w_drop3 = jnp.array([1.0, 0.0, 0.0])
    np.testing.assert_allclose(ref.segment_mean_ref(vals, seg, w_drop3, 1)[0], [2.0])
