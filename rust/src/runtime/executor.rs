//! Typed execution of compiled train/eval artifacts.
//!
//! An [`Executor`] owns one compiled `PjRtLoadedExecutable` plus its
//! [`ArtifactSpec`]. The hot path is [`Executor::execute_train`]: the
//! partition's static data tensors live on the device as `PjRtBuffer`s
//! (uploaded once by the worker), and only the parameters are re-uploaded
//! each iteration.

use super::artifact::ModelConfig;
use crate::util::rng::Rng;
#[cfg(feature = "xla")]
use {
    super::artifact::{ArtifactKind, ArtifactSpec},
    super::buffers::Tensor,
    super::client::RuntimeClient,
    anyhow::{ensure, Context, Result},
};

/// The model parameters as flat host vectors (lowering order).
#[derive(Clone, Debug)]
pub struct ParamSet {
    pub dims: Vec<Vec<usize>>,
    pub data: Vec<Vec<f32>>,
}

impl ParamSet {
    /// Glorot-uniform init for matrices, zeros for biases (mirrors
    /// `model.init_params` in spirit; exact values need not match Python —
    /// initialization happens on the Rust side only).
    pub fn init_glorot(cfg: &ModelConfig, rng: &mut Rng) -> ParamSet {
        let dims = cfg.param_shapes();
        let data = dims
            .iter()
            .map(|shape| {
                let len: usize = shape.iter().product();
                if shape.len() == 1 {
                    vec![0.0; len]
                } else {
                    let lim = (6.0 / (shape[0] + shape[1]) as f64).sqrt();
                    (0..len).map(|_| ((rng.f64() * 2.0 - 1.0) * lim) as f32).collect()
                }
            })
            .collect();
        ParamSet { dims, data }
    }

    /// Number of scalar parameters.
    pub fn num_elements(&self) -> usize {
        self.data.iter().map(|d| d.len()).sum()
    }

    /// L2 norm of all parameters (diagnostics).
    pub fn l2_norm(&self) -> f64 {
        self.data
            .iter()
            .flat_map(|d| d.iter())
            .map(|&x| (x as f64) * (x as f64))
            .sum::<f64>()
            .sqrt()
    }
}

/// Outputs of one `train_step` execution.
#[derive(Clone, Debug, Default)]
pub struct TrainOut {
    /// Sum of DAR-weighted losses over this partition.
    pub loss_sum: f32,
    /// Sum of the weights (for diagnostics / normalization checks).
    pub weight_sum: f32,
    /// Number of correct train-node predictions.
    pub correct: f32,
    /// Flattened gradients, one vec per parameter tensor, lowering order.
    pub grads: Vec<Vec<f32>>,
}

/// Outputs of one `eval_step` execution.
#[derive(Clone, Copy, Debug)]
pub struct EvalOut {
    pub correct: f32,
    pub count: f32,
    pub loss_sum: f32,
}

impl EvalOut {
    pub fn accuracy(&self) -> f64 {
        if self.count == 0.0 {
            f64::NAN
        } else {
            self.correct as f64 / self.count as f64
        }
    }
}

/// A compiled artifact ready to execute (needs the `xla` feature).
#[cfg(feature = "xla")]
pub struct Executor {
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
}

#[cfg(feature = "xla")]
impl Executor {
    /// Compile `spec`'s HLO file on `rt`.
    pub fn compile(rt: &RuntimeClient, spec: &ArtifactSpec) -> Result<Executor> {
        let t0 = std::time::Instant::now();
        let exe = rt.compile_hlo_file(&spec.file)?;
        crate::log_debug!("compiled {} in {:.2}s", spec.name, t0.elapsed().as_secs_f64());
        Ok(Executor { spec: spec.clone(), exe })
    }

    /// Upload a data batch (everything except params) to the device.
    pub fn upload_data(&self, rt: &RuntimeClient, data: &[Tensor]) -> Result<Vec<xla::PjRtBuffer>> {
        data.iter().map(|t| t.to_device(rt)).collect()
    }

    /// Execute with host params + device-resident data; returns the
    /// destructured output tuple as f32 vectors.
    fn run(
        &self,
        rt: &RuntimeClient,
        params: &ParamSet,
        data: &[&xla::PjRtBuffer],
    ) -> Result<Vec<Vec<f32>>> {
        let n_params = self.spec.model.param_shapes().len();
        ensure!(params.data.len() == n_params, "expected {n_params} params, got {}", params.data.len());
        let mut owned: Vec<xla::PjRtBuffer> = Vec::with_capacity(n_params);
        for (dims, d) in params.dims.iter().zip(&params.data) {
            owned.push(rt.to_device_f32(d, dims)?);
        }
        let args: Vec<&xla::PjRtBuffer> = owned.iter().chain(data.iter().copied()).collect();
        let result = self.exe.execute_b(&args).context("execute_b")?;
        // return_tuple=True => single output, a tuple literal.
        let lit = result[0][0].to_literal_sync()?;
        let parts = lit.to_tuple()?;
        parts.into_iter().map(|l| Ok(l.to_vec::<f32>()?)).collect::<Result<Vec<_>>>()
    }

    /// Execute a train step: `outputs = (loss_sum, weight_sum, correct, *grads)`.
    pub fn execute_train(
        &self,
        rt: &RuntimeClient,
        params: &ParamSet,
        device_data: &[&xla::PjRtBuffer],
    ) -> Result<TrainOut> {
        ensure!(self.spec.kind == ArtifactKind::Train, "not a train artifact");
        ensure!(device_data.len() == 7, "train step takes 7 data tensors");
        let outs = self.run(rt, params, device_data)?;
        let n_params = self.spec.model.param_shapes().len();
        ensure!(outs.len() == 3 + n_params, "unexpected output arity {}", outs.len());
        Ok(TrainOut {
            loss_sum: outs[0][0],
            weight_sum: outs[1][0],
            correct: outs[2][0],
            grads: outs[3..].to_vec(),
        })
    }

    /// Execute an eval step: `outputs = (correct, count, loss_sum)`.
    pub fn execute_eval(
        &self,
        rt: &RuntimeClient,
        params: &ParamSet,
        device_data: &[&xla::PjRtBuffer],
    ) -> Result<EvalOut> {
        ensure!(self.spec.kind == ArtifactKind::Eval, "not an eval artifact");
        ensure!(device_data.len() == 6, "eval step takes 6 data tensors");
        let outs = self.run(rt, params, device_data)?;
        ensure!(outs.len() == 3, "unexpected output arity {}", outs.len());
        Ok(EvalOut { correct: outs[0][0], count: outs[1][0], loss_sum: outs[2][0] })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::train::model::ModelKind;

    #[test]
    fn paramset_shapes_and_norm() {
        let cfg =
            ModelConfig { kind: ModelKind::Sage, layers: 2, feat_dim: 8, hidden: 16, classes: 4 };
        let mut rng = Rng::new(1);
        let p = ParamSet::init_glorot(&cfg, &mut rng);
        assert_eq!(p.dims.len(), 8);
        assert_eq!(p.num_elements(), cfg.num_params());
        assert!(p.l2_norm() > 0.0);
        // Biases are zero.
        assert!(p.data[1].iter().all(|&x| x == 0.0));
        // Matrices are bounded by the Glorot limit.
        let lim = (6.0_f64 / (8.0 + 16.0)).sqrt() as f32;
        assert!(p.data[0].iter().all(|&x| x.abs() <= lim));
    }

    #[test]
    fn paramset_deterministic() {
        let cfg =
            ModelConfig { kind: ModelKind::Sage, layers: 1, feat_dim: 4, hidden: 4, classes: 2 };
        let a = ParamSet::init_glorot(&cfg, &mut Rng::new(5));
        let b = ParamSet::init_glorot(&cfg, &mut Rng::new(5));
        assert_eq!(a.data, b.data);
    }
}

// End-to-end executor tests (needing real artifacts) live in
// `rust/tests/integration.rs`.
