//! Span tracing into preallocated per-thread ring buffers, exported as
//! Chrome trace-event JSON (open in Perfetto or `chrome://tracing`).
//!
//! The recording path is built for the steady-state epoch contract:
//!
//! * **Disabled is one atomic load.** [`span`]/[`record_since`] check a
//!   global flag and return immediately when tracing is off, so the
//!   default run pays one relaxed load per call site.
//! * **Enabled is clock + ring write.** Each thread owns a ring of
//!   [`RING_CAPACITY`] fixed-size events, allocated on the thread's first
//!   record (absorbed by warm-up) and never grown. When a ring is full the
//!   **oldest** event is overwritten and the `obs.trace.dropped` counter
//!   is bumped — profiling a long run keeps the most recent window rather
//!   than erroring or allocating.
//! * **No RNG, no float ops** — enabling tracing cannot perturb the
//!   training trajectory (`tests/dist_proc.rs` asserts bit-identity).
//!
//! Events carry an explicit logical `pid`/`tid` so one trace file can show
//! the whole fleet: the coordinator process records under pid 0 (tids are
//! per-thread), and the coordinator *synthesizes* spans for worker rank
//! `r` under pid `r + 1` from the phase breakdown each `StepResult`
//! carries (protocol v5) — workers never write trace files of their own.

use crate::util::binio;
use anyhow::{Context, Result};
use std::cell::RefCell;
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU32, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

/// Events retained per thread before drop-oldest kicks in (~640 KiB/thread
/// at 40 bytes per event).
pub const RING_CAPACITY: usize = 16 * 1024;

#[derive(Clone, Copy)]
struct Event {
    name: &'static str,
    pid: u32,
    tid: u32,
    start_us: u64,
    dur_us: u64,
}

struct Ring {
    events: Vec<Event>,
    head: usize, // next write slot once the ring is full
}

impl Ring {
    fn push(&mut self, ev: Event) {
        if self.events.len() < RING_CAPACITY {
            self.events.push(ev);
        } else {
            self.events[self.head] = ev;
            self.head = (self.head + 1) % RING_CAPACITY;
            dropped_counter().inc();
        }
    }
}

static ENABLED: AtomicBool = AtomicBool::new(false);
static EPOCH: OnceLock<Instant> = OnceLock::new();
static RINGS: Mutex<Vec<Arc<Mutex<Ring>>>> = Mutex::new(Vec::new());
static NEXT_TID: AtomicU32 = AtomicU32::new(1);
/// Logical pid this process records under (0 = coordinator).
static LOGICAL_PID: AtomicU32 = AtomicU32::new(0);

fn dropped_counter() -> &'static super::metrics::Counter {
    static C: OnceLock<&'static super::metrics::Counter> = OnceLock::new();
    C.get_or_init(|| super::metrics::counter("obs.trace.dropped"))
}

thread_local! {
    static LOCAL: RefCell<Option<(u32, Arc<Mutex<Ring>>)>> = const { RefCell::new(None) };
}

/// Turn recording on (idempotent). Also pins the trace clock epoch and
/// registers the overflow counter, so no later call allocates lazily on
/// the hot path.
pub fn enable() {
    let _ = EPOCH.get_or_init(Instant::now);
    let _ = dropped_counter();
    ENABLED.store(true, Ordering::Relaxed);
}

/// Turn recording off (spans become no-ops again; recorded events stay
/// buffered for export).
pub fn disable() {
    ENABLED.store(false, Ordering::Relaxed);
}

pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Set the logical pid this process records under. The coordinator keeps
/// the default 0; nothing else currently needs another value because
/// worker spans are synthesized coordinator-side.
pub fn set_logical_pid(pid: u32) {
    LOGICAL_PID.store(pid, Ordering::Relaxed);
}

fn now_us() -> u64 {
    EPOCH.get().map(|e| e.elapsed().as_micros() as u64).unwrap_or(0)
}

fn instant_us(t: Instant) -> u64 {
    let e = match EPOCH.get() {
        Some(e) => *e,
        None => return 0,
    };
    t.checked_duration_since(e).map(|d| d.as_micros() as u64).unwrap_or(0)
}

fn push_event(ev: Event) {
    LOCAL.with(|slot| {
        let mut slot = slot.borrow_mut();
        if slot.is_none() {
            // First record on this thread: allocate its ring once and
            // register it for export. Warm-up absorbs this allocation.
            let ring = Arc::new(Mutex::new(Ring {
                events: Vec::with_capacity(RING_CAPACITY),
                head: 0,
            }));
            let tid = NEXT_TID.fetch_add(1, Ordering::Relaxed);
            RINGS.lock().expect("trace rings poisoned").push(Arc::clone(&ring));
            *slot = Some((tid, ring));
        }
        let (tid, ring) = slot.as_ref().expect("just initialized");
        let mut ev = ev;
        if ev.tid == u32::MAX {
            ev.tid = *tid;
        }
        ring.lock().expect("trace ring poisoned").push(ev);
    });
}

/// RAII span: records one complete (`ph: "X"`) event on drop. Obtain via
/// [`span`]; when tracing is disabled the guard is inert.
pub struct Span {
    name: &'static str,
    t0: Option<Instant>,
}

impl Drop for Span {
    fn drop(&mut self) {
        if let Some(t0) = self.t0 {
            record_since(self.name, t0);
        }
    }
}

/// Begin a span; it ends (and records) when the guard drops.
pub fn span(name: &'static str) -> Span {
    Span { name, t0: if enabled() { Some(Instant::now()) } else { None } }
}

/// Record a completed span that began at `t0` and ends now.
pub fn record_since(name: &'static str, t0: Instant) {
    if !enabled() {
        return;
    }
    let start = instant_us(t0);
    push_event(Event {
        name,
        pid: LOGICAL_PID.load(Ordering::Relaxed),
        tid: u32::MAX,
        start_us: start,
        dur_us: now_us().saturating_sub(start),
    });
}

/// Record a completed span on the current thread's ring with an explicit
/// start anchor and an externally measured duration — used when a phase
/// split is timed inside a kernel and mirrored into the trace afterwards.
pub fn record_at(name: &'static str, start: Instant, dur_s: f64) {
    if !enabled() {
        return;
    }
    push_event(Event {
        name,
        pid: LOGICAL_PID.load(Ordering::Relaxed),
        tid: u32::MAX,
        start_us: instant_us(start),
        dur_us: (dur_s * 1e6) as u64,
    });
}

/// Record a span on behalf of another logical process — the coordinator
/// uses this to place worker-rank phases (from the wire breakdown) under
/// their own pids. `start` anchors the span on the shared trace clock;
/// `dur_s` is the remotely measured duration.
pub fn record_synth(name: &'static str, pid: u32, tid: u32, start: Instant, dur_s: f64) {
    if !enabled() {
        return;
    }
    push_event(Event {
        name,
        pid,
        tid,
        start_us: instant_us(start),
        dur_us: (dur_s * 1e6) as u64,
    });
}

/// Total events overwritten by drop-oldest since startup.
pub fn dropped() -> u64 {
    dropped_counter().get()
}

/// Serializes tests that toggle the process-global [`enabled`] flag: the
/// library test binary runs tests concurrently, and a `disable()` in one
/// test would race-dependently strip spans another test is asserting on.
#[cfg(test)]
pub(crate) static TEST_FLAG_LOCK: Mutex<()> = Mutex::new(());

fn collect() -> Vec<Event> {
    let rings = RINGS.lock().expect("trace rings poisoned");
    let mut all = Vec::new();
    for ring in rings.iter() {
        let ring = ring.lock().expect("trace ring poisoned");
        // Oldest-first: [head..] then [..head] once the ring has wrapped.
        all.extend_from_slice(&ring.events[ring.head..]);
        all.extend_from_slice(&ring.events[..ring.head]);
    }
    all.sort_by_key(|e| e.start_us);
    all
}

/// Render everything recorded so far as a Chrome trace-event JSON array:
/// one `"ph": "M"` `process_name` metadata record per distinct pid
/// (`coordinator` / `worker rN`), then the `"ph": "X"` complete events.
pub fn chrome_trace_json() -> String {
    use std::fmt::Write as _;
    let events = collect();
    let mut pids: Vec<u32> = events.iter().map(|e| e.pid).collect();
    pids.sort_unstable();
    pids.dedup();
    let mut out = String::from("[\n");
    let mut first = true;
    for pid in pids {
        if !first {
            out.push_str(",\n");
        }
        first = false;
        let name =
            if pid == 0 { "coordinator".to_string() } else { format!("worker r{}", pid - 1) };
        let _ = write!(
            out,
            "{{\"name\": \"process_name\", \"ph\": \"M\", \"pid\": {pid}, \"tid\": 0, \"args\": {{\"name\": \"{name}\"}}}}"
        );
    }
    for e in &events {
        if !first {
            out.push_str(",\n");
        }
        first = false;
        let _ = write!(
            out,
            "{{\"name\": \"{}\", \"ph\": \"X\", \"ts\": {}, \"dur\": {}, \"pid\": {}, \"tid\": {}}}",
            e.name, e.start_us, e.dur_us, e.pid, e.tid
        );
    }
    out.push_str("\n]\n");
    out
}

/// Write the Chrome trace to `path` atomically (tmp sibling + rename), so
/// a crash mid-export never leaves a half-written file where a previous
/// good trace was.
pub fn write_chrome(path: &Path) -> Result<()> {
    let json = chrome_trace_json();
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)
                .with_context(|| format!("creating trace directory {}", parent.display()))?;
        }
    }
    let tmp = binio::tmp_sibling(path);
    let guard = binio::TmpGuard::new(tmp.clone());
    {
        use std::io::Write as _;
        let mut f = std::fs::File::create(&tmp)
            .with_context(|| format!("creating trace tmp {}", tmp.display()))?;
        f.write_all(json.as_bytes())
            .with_context(|| format!("writing trace tmp {}", tmp.display()))?;
        f.sync_all().with_context(|| format!("fsyncing trace tmp {}", tmp.display()))?;
    }
    binio::commit_replace(&tmp, path)?;
    guard.disarm();
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    #[test]
    fn spans_record_and_export_as_chrome_trace_json() {
        let _guard = TEST_FLAG_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        enable();
        {
            let _s = span("test.trace.outer");
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        record_synth("test.trace.worker_phase", 3, 0, Instant::now(), 0.001);
        let text = chrome_trace_json();
        let doc = json::parse(text.as_bytes()).expect("chrome trace is valid JSON");
        let arr = doc.as_arr().expect("top level is an array");
        assert!(!arr.is_empty());
        let mut saw_outer = false;
        let mut saw_worker_pid = false;
        let mut saw_meta = false;
        for ev in arr {
            let name = ev.get("name").and_then(|n| n.as_str()).unwrap();
            let ph = ev.get("ph").and_then(|p| p.as_str()).unwrap();
            match ph {
                "M" => {
                    assert_eq!(name, "process_name");
                    saw_meta = true;
                }
                "X" => {
                    assert!(ev.get("ts").and_then(|t| t.as_f64()).is_some());
                    assert!(ev.get("dur").and_then(|t| t.as_f64()).is_some());
                    if name == "test.trace.outer" {
                        let dur = ev.get("dur").and_then(|t| t.as_f64()).unwrap();
                        assert!(dur >= 1_000.0, "2ms span recorded {dur}us");
                        saw_outer = true;
                    }
                    if name == "test.trace.worker_phase" {
                        assert_eq!(ev.get("pid").and_then(|p| p.as_u64()), Some(3));
                        saw_worker_pid = true;
                    }
                }
                other => panic!("unexpected ph {other:?}"),
            }
        }
        assert!(saw_meta && saw_outer && saw_worker_pid);
    }

    #[test]
    fn disabled_spans_are_inert() {
        let _guard = TEST_FLAG_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        // A fresh thread has no ring; when tracing is off, the span guard
        // must not create one.
        std::thread::spawn(|| {
            disable();
            let before = dropped();
            {
                let _s = span("test.trace.noop");
            }
            record_since("test.trace.noop2", Instant::now());
            assert_eq!(dropped(), before);
            LOCAL.with(|slot| assert!(slot.borrow().is_none(), "disabled span touched the ring"));
        })
        .join()
        .unwrap();
    }

    #[test]
    fn overflow_drops_oldest_and_counts() {
        let _guard = TEST_FLAG_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        std::thread::spawn(|| {
            enable();
            let t0 = Instant::now();
            let before = dropped();
            for _ in 0..RING_CAPACITY + 10 {
                record_since("test.trace.flood", t0);
            }
            assert!(dropped() >= before + 10, "overflow was not surfaced as a counter");
        })
        .join()
        .unwrap();
    }
}
