//! Synthetic graph generators.
//!
//! The paper evaluates on Reddit, Yelp, ogbn-products and ogbn-papers100M.
//! Those datasets are not available here, so each is *simulated* by a
//! generator matched on the statistics that drive the paper's phenomena:
//!
//! * **degree distribution** (power law) — controls the replication-factor
//!   imbalance of Theorem 4.2 and therefore how much DAR matters;
//! * **density** (average degree) — controls compute vs. communication
//!   balance in Table 1;
//! * **homophilic community structure** — controls whether partition-local
//!   training can recover accuracy (Theorem 4.3 assumes homophily), supplied
//!   by overlaying an SBM on top of the degree sequence.
//!
//! See `DESIGN.md` §2 for the substitution rationale.

pub mod ba;
pub mod chung_lu;
pub mod erdos;
pub mod rmat;
pub mod sbm;

pub use ba::barabasi_albert;
pub use chung_lu::{
    chung_lu, chung_lu_pairs, chung_lu_pairs_chunked, ChungLuPairsChunked, power_law_degrees,
};
pub use erdos::erdos_renyi;
pub use rmat::{rmat, rmat_pairs, rmat_pairs_chunked, RmatPairsChunked, RmatParams};
pub use sbm::{degree_corrected_sbm, planted_communities};
