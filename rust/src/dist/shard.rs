//! The partition shard store: per-partition on-disk artifacts.
//!
//! `cofree shard --partitions N --out dir/` runs the partitioning pipeline
//! once and writes one self-describing binary file per partition
//! (`shard_0000.bin`, …) plus a human-readable `manifest.json`. A shard
//! holds everything a worker process needs to train on its partition and
//! **nothing else** — the local CSR (as the sorted canonical local edge
//! list it was materialized from), the local→global id table, the DAR
//! weights, and the partition's rows of the feature/label/split arrays —
//! so no worker process ever materializes the full graph. Workers stream
//! the file front-to-back in one pass ([`Shard::read`]); every f32
//! round-trips bit-exactly, which is load-bearing for the cross-process
//! determinism contract.
//!
//! Format (version 1, little-endian, shared [`binio`] header helpers):
//!
//! ```text
//! magic "COFREESH" | u32 version
//! u32 part_id | u32 num_parts
//! u32×4 model (layers, feat_dim, hidden, classes)
//! u64 seed | u64 global_nodes | u64 global_edges
//! u32s global_ids            (len n_local)
//! u32s local edge endpoints  (len 2·m_local, canonical order, u<v sorted)
//! f32s dar weights           (len n_local)
//! f32s features              (len n_local·feat_dim, row-major)
//! u32s labels                (len n_local)
//! bytes split masks          (len n_local)
//! ```

use crate::graph::{Dataset, Graph, NodeData};
use crate::partition::VertexCut;
use crate::runtime::ModelConfig;
use crate::train::engine::model_config;
use crate::train::model::ModelKind;
use crate::train::tensorize::{tensorize_subgraph, tensorize_subgraph_ref, NodeDataRef, TrainBatch};
use crate::util::binio;
use crate::util::mmap::Mmap;
use anyhow::{bail, ensure, Context, Result};
use std::io::{BufReader, BufWriter, Write};
use std::path::{Path, PathBuf};

pub const SHARD_MAGIC: &[u8; 8] = b"COFREESH";
pub const SHARD_VERSION: u32 = 1;

/// One partition's self-contained training data, as stored on disk.
#[derive(Clone, Debug)]
pub struct Shard {
    pub part_id: usize,
    pub num_parts: usize,
    pub model: ModelConfig,
    /// Dataset seed (provenance; not consumed at train time).
    pub seed: u64,
    /// Full-graph sizes, for manifest cross-checks and sanity reporting.
    pub global_nodes: usize,
    pub global_edges: usize,
    /// Local id → global id (sorted ascending, as materialized).
    pub global_ids: Vec<u32>,
    /// The partition's local topology.
    pub local: Graph,
    /// DAR weight per local node.
    pub dar: Vec<f32>,
    /// The partition's rows of features/labels/splits, locally indexed.
    pub data: NodeData,
}

/// Canonical shard file name for a partition.
pub fn shard_file_name(part_id: usize) -> String {
    format!("shard_{part_id:04}.bin")
}

impl Shard {
    /// Gather partition `i` of a vertex cut into a shard.
    pub fn from_part(ds: &Dataset, vc: &VertexCut, weights: &[Vec<f32>], i: usize, seed: u64) -> Shard {
        let part = &vc.parts[i];
        let nd = &ds.data;
        let n_local = part.num_nodes();
        let d = nd.dim;
        let mut features = Vec::with_capacity(n_local * d);
        let mut labels = Vec::with_capacity(n_local);
        let mut split = Vec::with_capacity(n_local);
        for &gid in &part.global_ids {
            features.extend_from_slice(nd.feature(gid));
            labels.push(nd.labels[gid as usize]);
            split.push(nd.split[gid as usize]);
        }
        Shard {
            part_id: i,
            num_parts: vc.num_parts,
            model: model_config(ds),
            seed,
            global_nodes: ds.graph.num_nodes(),
            global_edges: ds.graph.num_edges(),
            global_ids: part.global_ids.clone(),
            local: part.local.clone(),
            dar: weights[i].clone(),
            data: NodeData {
                features,
                dim: d,
                labels,
                num_classes: nd.num_classes,
                split,
            },
        }
    }

    /// Write to `path`; returns bytes written.
    pub fn write(&self, path: &Path) -> Result<u64> {
        let n_local = self.global_ids.len();
        ensure!(self.dar.len() == n_local, "dar length mismatch");
        ensure!(self.data.labels.len() == n_local, "labels length mismatch");
        ensure!(self.data.split.len() == n_local, "split length mismatch");
        ensure!(self.data.features.len() == n_local * self.data.dim, "features length mismatch");
        let f = std::fs::File::create(path).with_context(|| format!("create {path:?}"))?;
        let mut w = BufWriter::new(f);
        binio::write_magic(&mut w, SHARD_MAGIC)?;
        binio::write_version(&mut w, SHARD_VERSION)?;
        binio::write_u32(&mut w, self.part_id as u32)?;
        binio::write_u32(&mut w, self.num_parts as u32)?;
        for d in [self.model.layers, self.model.feat_dim, self.model.hidden, self.model.classes] {
            binio::write_u32(&mut w, d as u32)?;
        }
        binio::write_u64(&mut w, self.seed)?;
        binio::write_u64(&mut w, self.global_nodes as u64)?;
        binio::write_u64(&mut w, self.global_edges as u64)?;
        binio::write_u32s(&mut w, &self.global_ids)?;
        let flat: Vec<u32> = self.local.edges().iter().flat_map(|&(u, v)| [u, v]).collect();
        binio::write_u32s(&mut w, &flat)?;
        binio::write_f32s(&mut w, &self.dar)?;
        binio::write_f32s(&mut w, &self.data.features)?;
        binio::write_u32s(&mut w, &self.data.labels)?;
        binio::write_bytes(&mut w, &self.data.split)?;
        w.flush()?;
        Ok(std::fs::metadata(path)?.len())
    }

    /// Stream a shard from `path`, rebuilding the local CSR from the sorted
    /// canonical edge list (the same construction the partitioner used, so
    /// the in-memory graph is byte-identical to the one that was written).
    pub fn read(path: &Path) -> Result<Shard> {
        let f = std::fs::File::open(path).with_context(|| format!("open {path:?}"))?;
        let mut r = BufReader::new(f);
        binio::expect_magic(&mut r, SHARD_MAGIC, "cofree partition shard")
            .with_context(|| format!("reading {path:?}"))?;
        binio::expect_version(&mut r, SHARD_VERSION, "partition shard")?;
        let part_id = binio::read_u32(&mut r)? as usize;
        let num_parts = binio::read_u32(&mut r)? as usize;
        // Shards store dims only — the arrays are architecture-agnostic;
        // the model kind travels in the wire Config frame. The nominal
        // kind here is the default (Sage); consumers compare dims.
        let model = ModelConfig {
            kind: ModelKind::Sage,
            layers: binio::read_u32(&mut r)? as usize,
            feat_dim: binio::read_u32(&mut r)? as usize,
            hidden: binio::read_u32(&mut r)? as usize,
            classes: binio::read_u32(&mut r)? as usize,
        };
        let seed = binio::read_u64(&mut r)?;
        let global_nodes = binio::read_u64(&mut r)? as usize;
        let global_edges = binio::read_u64(&mut r)? as usize;
        ensure!(part_id < num_parts, "shard part_id {part_id} out of range {num_parts}");
        let global_ids = binio::read_u32s(&mut r).context("reading id table")?;
        let flat = binio::read_u32s(&mut r).context("reading local edges")?;
        ensure!(flat.len() % 2 == 0, "corrupt local edge array: odd endpoint count");
        let n_local = global_ids.len();
        let edges: Vec<(u32, u32)> = flat.chunks_exact(2).map(|c| (c[0], c[1])).collect();
        for (k, &(u, v)) in edges.iter().enumerate() {
            ensure!(
                u < v && (v as usize) < n_local,
                "corrupt local edge {k}: ({u},{v}) with n_local {n_local}"
            );
            if k > 0 {
                ensure!(edges[k - 1] < edges[k], "local edges not sorted/unique at {k}");
            }
        }
        let local = Graph::from_sorted_edges(n_local, edges);
        let dar = binio::read_f32s(&mut r).context("reading dar weights")?;
        let features = binio::read_f32s(&mut r).context("reading features")?;
        let labels = binio::read_u32s(&mut r).context("reading labels")?;
        let split = binio::read_bytes(&mut r).context("reading split masks")?;
        ensure!(dar.len() == n_local, "dar length {} != {n_local}", dar.len());
        ensure!(labels.len() == n_local, "labels length {} != {n_local}", labels.len());
        ensure!(split.len() == n_local, "split length {} != {n_local}", split.len());
        ensure!(
            features.len() == n_local * model.feat_dim,
            "features length {} != n_local {n_local} × feat_dim {}",
            features.len(),
            model.feat_dim
        );
        Ok(Shard {
            part_id,
            num_parts,
            model,
            seed,
            global_nodes,
            global_edges,
            global_ids,
            local,
            dar,
            data: NodeData {
                features,
                dim: model.feat_dim,
                labels,
                num_classes: model.classes,
                split,
            },
        })
    }

    /// Tensorize this shard at a padded shape — produces the exact batch
    /// `tensorize_partition` builds from the full graph for this partition
    /// (the id map is the identity over local rows, and the stored rows
    /// were gathered with the same global ids).
    pub fn tensorize(&self, n_pad: usize, e_pad: usize) -> Result<TrainBatch> {
        let ids: Vec<u32> = (0..self.global_ids.len() as u32).collect();
        tensorize_subgraph(&ids, &self.local, &self.data, &self.dar, n_pad, e_pad)
    }
}

// ---------------------------------------------------------------------------
// Zero-copy load path.
// ---------------------------------------------------------------------------

/// Byte range of one array inside a mapped shard file.
type ByteRange = (usize, usize);

/// Parsed header + array ranges of a shard byte image (shared validation
/// for the zero-copy path; the layout is the one documented at the top of
/// this module and written by [`Shard::write`]).
struct ParsedShard {
    part_id: usize,
    num_parts: usize,
    model: ModelConfig,
    seed: u64,
    global_nodes: usize,
    global_edges: usize,
    n_local: usize,
    global_ids: ByteRange,
    edges: ByteRange,
    dar: ByteRange,
    features: ByteRange,
    labels: ByteRange,
    split: ByteRange,
}

/// Read a `u64`-length-prefixed array's byte range off the cursor.
fn take_array(
    bytes: &[u8],
    r: &mut &[u8],
    elem: usize,
    what: &str,
) -> Result<(usize, ByteRange)> {
    let len = binio::read_u64(r).with_context(|| format!("reading {what} length"))? as usize;
    let nbytes = len
        .checked_mul(elem)
        .with_context(|| format!("corrupt {what}: length {len} overflows"))?;
    ensure!(
        r.len() >= nbytes,
        "truncated shard: {what} wants {nbytes} bytes, {} remain",
        r.len()
    );
    let start = bytes.len() - r.len();
    *r = &r[nbytes..];
    Ok((len, (start, start + nbytes)))
}

fn parse_shard_bytes(bytes: &[u8], path: &Path) -> Result<ParsedShard> {
    let mut r: &[u8] = bytes;
    binio::expect_magic(&mut r, SHARD_MAGIC, "cofree partition shard")
        .with_context(|| format!("reading {path:?}"))?;
    binio::expect_version(&mut r, SHARD_VERSION, "partition shard")?;
    let part_id = binio::read_u32(&mut r)? as usize;
    let num_parts = binio::read_u32(&mut r)? as usize;
    let model = ModelConfig {
        kind: ModelKind::Sage,
        layers: binio::read_u32(&mut r)? as usize,
        feat_dim: binio::read_u32(&mut r)? as usize,
        hidden: binio::read_u32(&mut r)? as usize,
        classes: binio::read_u32(&mut r)? as usize,
    };
    let seed = binio::read_u64(&mut r)?;
    let global_nodes = binio::read_u64(&mut r)? as usize;
    let global_edges = binio::read_u64(&mut r)? as usize;
    ensure!(part_id < num_parts, "shard part_id {part_id} out of range {num_parts}");
    let (n_local, global_ids) = take_array(bytes, &mut r, 4, "id table")?;
    let (flat_len, edges) = take_array(bytes, &mut r, 4, "local edges")?;
    ensure!(flat_len % 2 == 0, "corrupt local edge array: odd endpoint count");
    let (dar_len, dar) = take_array(bytes, &mut r, 4, "dar weights")?;
    let (feat_len, features) = take_array(bytes, &mut r, 4, "features")?;
    let (labels_len, labels) = take_array(bytes, &mut r, 4, "labels")?;
    let (split_len, split) = take_array(bytes, &mut r, 1, "split masks")?;
    ensure!(r.is_empty(), "corrupt shard: {} trailing bytes", r.len());
    ensure!(dar_len == n_local, "dar length {dar_len} != {n_local}");
    ensure!(labels_len == n_local, "labels length {labels_len} != {n_local}");
    ensure!(split_len == n_local, "split length {split_len} != {n_local}");
    ensure!(
        feat_len == n_local * model.feat_dim,
        "features length {feat_len} != n_local {n_local} × feat_dim {}",
        model.feat_dim
    );
    Ok(ParsedShard {
        part_id,
        num_parts,
        model,
        seed,
        global_nodes,
        global_edges,
        n_local,
        global_ids,
        edges,
        dar,
        features,
        labels,
        split,
    })
}

/// Alignment-checked reinterpretation of a little-endian byte range as a
/// 4-byte-element slice. Sound for any `T` whose every bit pattern is
/// valid (u32, f32); the caller guarantees the target is little-endian.
fn reinterpret_4byte<T>(bytes: &[u8]) -> Result<&[T]> {
    // SAFETY: u32/f32 accept all bit patterns; align_to itself verifies
    // the pointer alignment and we refuse any remainder.
    let (pre, mid, post) = unsafe { bytes.align_to::<T>() };
    ensure!(
        pre.is_empty() && post.is_empty(),
        "mapped shard array is not 4-byte aligned (offset drift?)"
    );
    Ok(mid)
}

/// Array storage of a [`MappedShard`]: borrowed straight out of the page
/// cache when the platform allows, owned copies otherwise.
enum ShardArrays {
    Mapped {
        map: Mmap,
        global_ids: ByteRange,
        dar: ByteRange,
        features: ByteRange,
        labels: ByteRange,
        split: ByteRange,
    },
    Owned {
        global_ids: Vec<u32>,
        dar: Vec<f32>,
        features: Vec<f32>,
        labels: Vec<u32>,
        split: Vec<u8>,
    },
}

/// A shard opened through the zero-copy load path: the file is mmapped,
/// the header and array layout are validated in place, and the id table,
/// DAR weights, feature rows, labels and split masks are **borrowed from
/// the mapping** — a worker starts without deserializing a private copy
/// of any of them (the local CSR is rebuilt, which is graph construction,
/// not a copy). On big-endian targets, or if the mapping cannot be
/// aligned, the loader transparently falls back to the streamed
/// [`Shard::read`] copy — byte-identical contents either way
/// (property-tested below).
///
/// Shard files are written-once artifacts; as with any mmap reader,
/// truncating one while a worker has it mapped is undefined behavior at
/// the file level (the process may fault). Don't rewrite a live store.
pub struct MappedShard {
    pub part_id: usize,
    pub num_parts: usize,
    pub model: ModelConfig,
    /// Dataset seed (provenance; not consumed at train time).
    pub seed: u64,
    pub global_nodes: usize,
    pub global_edges: usize,
    /// The partition's local topology, rebuilt from the stored sorted
    /// canonical edge list with the same `from_sorted_edges` construction
    /// the partitioner used.
    pub local: Graph,
    arrays: ShardArrays,
}

impl MappedShard {
    /// Open `path` through the zero-copy path (with portable fallback).
    pub fn open(path: &Path) -> Result<MappedShard> {
        let map = Mmap::open(path)?;
        let parsed = parse_shard_bytes(map.bytes(), path)?;
        // Decode the edge list (endian-safe per-element reads) and rebuild
        // the CSR exactly like Shard::read does.
        let flat = &map.bytes()[parsed.edges.0..parsed.edges.1];
        let n_local = parsed.n_local;
        let edges: Vec<(u32, u32)> = flat
            .chunks_exact(8)
            .map(|c| {
                (
                    u32::from_le_bytes([c[0], c[1], c[2], c[3]]),
                    u32::from_le_bytes([c[4], c[5], c[6], c[7]]),
                )
            })
            .collect();
        for (k, &(u, v)) in edges.iter().enumerate() {
            ensure!(
                u < v && (v as usize) < n_local,
                "corrupt local edge {k}: ({u},{v}) with n_local {n_local}"
            );
            if k > 0 {
                ensure!(edges[k - 1] < edges[k], "local edges not sorted/unique at {k}");
            }
        }
        let local = Graph::from_sorted_edges(n_local, edges);
        // Zero-copy needs a little-endian target (the arrays are stored LE
        // and reinterpreted in place) and 4-byte-aligned ranges.
        let zero_copy = cfg!(target_endian = "little")
            && reinterpret_4byte::<u32>(&map.bytes()[parsed.global_ids.0..parsed.global_ids.1])
                .is_ok()
            && reinterpret_4byte::<f32>(&map.bytes()[parsed.dar.0..parsed.dar.1]).is_ok()
            && reinterpret_4byte::<f32>(&map.bytes()[parsed.features.0..parsed.features.1])
                .is_ok()
            && reinterpret_4byte::<u32>(&map.bytes()[parsed.labels.0..parsed.labels.1]).is_ok();
        let arrays = if zero_copy {
            ShardArrays::Mapped {
                map,
                global_ids: parsed.global_ids,
                dar: parsed.dar,
                features: parsed.features,
                labels: parsed.labels,
                split: parsed.split,
            }
        } else {
            // Portable fallback: one streamed read, owned arrays.
            let shard = Shard::read(path)?;
            ShardArrays::Owned {
                global_ids: shard.global_ids,
                dar: shard.dar,
                features: shard.data.features,
                labels: shard.data.labels,
                split: shard.data.split,
            }
        };
        Ok(MappedShard {
            part_id: parsed.part_id,
            num_parts: parsed.num_parts,
            model: parsed.model,
            seed: parsed.seed,
            global_nodes: parsed.global_nodes,
            global_edges: parsed.global_edges,
            local,
            arrays,
        })
    }

    /// Whether the arrays are truly borrowed from the mapping.
    pub fn is_zero_copy(&self) -> bool {
        matches!(&self.arrays, ShardArrays::Mapped { map, .. } if map.is_mapped())
    }

    pub fn n_local(&self) -> usize {
        self.global_ids().len()
    }

    /// Local id → global id (sorted ascending, as materialized).
    pub fn global_ids(&self) -> &[u32] {
        match &self.arrays {
            ShardArrays::Mapped { map, global_ids, .. } => {
                reinterpret_4byte(&map.bytes()[global_ids.0..global_ids.1])
                    .expect("alignment verified at open")
            }
            ShardArrays::Owned { global_ids, .. } => global_ids,
        }
    }

    /// DAR weight per local node.
    pub fn dar(&self) -> &[f32] {
        match &self.arrays {
            ShardArrays::Mapped { map, dar, .. } => {
                reinterpret_4byte(&map.bytes()[dar.0..dar.1]).expect("alignment verified at open")
            }
            ShardArrays::Owned { dar, .. } => dar,
        }
    }

    /// The partition's feature rows, row-major `[n_local, feat_dim]`.
    pub fn features(&self) -> &[f32] {
        match &self.arrays {
            ShardArrays::Mapped { map, features, .. } => {
                reinterpret_4byte(&map.bytes()[features.0..features.1])
                    .expect("alignment verified at open")
            }
            ShardArrays::Owned { features, .. } => features,
        }
    }

    /// Class id per local node.
    pub fn labels(&self) -> &[u32] {
        match &self.arrays {
            ShardArrays::Mapped { map, labels, .. } => {
                reinterpret_4byte(&map.bytes()[labels.0..labels.1])
                    .expect("alignment verified at open")
            }
            ShardArrays::Owned { labels, .. } => labels,
        }
    }

    /// Split mask per local node (0 train, 1 val, 2 test).
    pub fn split(&self) -> &[u8] {
        match &self.arrays {
            ShardArrays::Mapped { map, split, .. } => &map.bytes()[split.0..split.1],
            ShardArrays::Owned { split, .. } => split,
        }
    }

    /// Tensorize straight off the mapped arrays — produces the exact batch
    /// [`Shard::tensorize`] (and therefore the in-process engine) builds
    /// for this partition.
    pub fn tensorize(&self, n_pad: usize, e_pad: usize) -> Result<TrainBatch> {
        let ids: Vec<u32> = (0..self.n_local() as u32).collect();
        let nd = NodeDataRef {
            features: self.features(),
            dim: self.model.feat_dim,
            labels: self.labels(),
            num_classes: self.model.classes,
            split: self.split(),
        };
        tensorize_subgraph_ref(&ids, &self.local, nd, self.dar(), n_pad, e_pad)
    }

    /// Materialize an owned [`Shard`] (copies — used by parity tests).
    pub fn to_shard(&self) -> Shard {
        Shard {
            part_id: self.part_id,
            num_parts: self.num_parts,
            model: self.model,
            seed: self.seed,
            global_nodes: self.global_nodes,
            global_edges: self.global_edges,
            global_ids: self.global_ids().to_vec(),
            local: self.local.clone(),
            dar: self.dar().to_vec(),
            data: NodeData {
                features: self.features().to_vec(),
                dim: self.model.feat_dim,
                labels: self.labels().to_vec(),
                num_classes: self.model.classes,
                split: self.split().to_vec(),
            },
        }
    }
}

/// Aggregate output of [`write_shards`].
#[derive(Clone, Debug)]
pub struct ShardSetStats {
    /// `(file name, bytes)` per shard, part order.
    pub files: Vec<(String, u64)>,
    pub total_bytes: u64,
}

/// Write every partition of `vc` as a shard under `dir` (created if
/// missing), plus `manifest.json`.
pub fn write_shards(
    ds: &Dataset,
    vc: &VertexCut,
    weights: &[Vec<f32>],
    seed: u64,
    dir: &Path,
) -> Result<ShardSetStats> {
    ensure!(weights.len() == vc.parts.len(), "one weight table per part");
    std::fs::create_dir_all(dir).with_context(|| format!("create {dir:?}"))?;
    let mut files = Vec::with_capacity(vc.parts.len());
    let mut total_bytes = 0u64;
    for i in 0..vc.parts.len() {
        let shard = Shard::from_part(ds, vc, weights, i, seed);
        let name = shard_file_name(i);
        let bytes = shard.write(&dir.join(&name))?;
        total_bytes += bytes;
        files.push((name, bytes));
    }
    let stats = ShardSetStats { files, total_bytes };
    write_manifest(ds, vc, seed, dir, &stats)?;
    Ok(stats)
}

/// Write `manifest.json` (documentation + tooling aid; the shard files are
/// self-describing, so nothing at train time parses this back).
fn write_manifest(
    ds: &Dataset,
    vc: &VertexCut,
    seed: u64,
    dir: &Path,
    stats: &ShardSetStats,
) -> Result<()> {
    let model = model_config(ds);
    let mut shards = String::new();
    for (i, (name, bytes)) in stats.files.iter().enumerate() {
        if i > 0 {
            shards.push_str(",\n    ");
        }
        let part = &vc.parts[i];
        shards.push_str(&format!(
            "{{\"file\": \"{name}\", \"part_id\": {i}, \"nodes\": {}, \"edges\": {}, \"bytes\": {bytes}}}",
            part.num_nodes(),
            part.num_edges()
        ));
    }
    let json = format!(
        "{{\n  \"format\": \"cofree-shards-v{SHARD_VERSION}\",\n  \"dataset\": \"{}\",\n  \"seed\": {seed},\n  \"num_parts\": {},\n  \"model\": {{\"layers\": {}, \"feat_dim\": {}, \"hidden\": {}, \"classes\": {}}},\n  \"graph\": {{\"nodes\": {}, \"edges\": {}}},\n  \"total_bytes\": {},\n  \"shards\": [\n    {shards}\n  ]\n}}\n",
        ds.name,
        vc.num_parts,
        model.layers,
        model.feat_dim,
        model.hidden,
        model.classes,
        ds.graph.num_nodes(),
        ds.graph.num_edges(),
        stats.total_bytes
    );
    let mut f = std::fs::File::create(dir.join("manifest.json"))?;
    f.write_all(json.as_bytes())?;
    Ok(())
}

/// List the shard files in `dir`, sorted by part id (file-name order).
/// Errors if the directory holds no shards.
pub fn shard_files(dir: &Path) -> Result<Vec<PathBuf>> {
    let mut out: Vec<PathBuf> = std::fs::read_dir(dir)
        .with_context(|| format!("read shard dir {dir:?}"))?
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| {
            p.file_name()
                .and_then(|n| n.to_str())
                .map(|n| n.starts_with("shard_") && n.ends_with(".bin"))
                .unwrap_or(false)
        })
        .collect();
    if out.is_empty() {
        bail!("no shard_*.bin files in {dir:?} (run `cofree shard --out {}` first)", dir.display());
    }
    out.sort();
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::features::{synthesize, FeatureParams};
    use crate::partition::testutil::graph_zoo;
    use crate::partition::{algorithm, dar_weights, Reweighting, ALGORITHMS};
    use crate::util::rng::Rng;

    fn tmp_dir(name: &str) -> PathBuf {
        let p = std::env::temp_dir().join(format!("cofree_shards_{name}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&p);
        p
    }

    fn dataset_for(g: &Graph, seed: u64) -> Dataset {
        let mut rng = Rng::new(seed);
        let n = g.num_nodes();
        let comm: Vec<u32> = (0..n).map(|i| (i % 4) as u32).collect();
        let nd = synthesize(&comm, 4, &FeatureParams { dim: 6, ..Default::default() }, &mut rng);
        Dataset { name: format!("zoo-{seed}"), graph: g.clone(), data: nd, layers: 2, hidden: 8 }
    }

    /// Adjacency-row snapshot for byte-identity comparisons.
    fn rows(g: &Graph) -> Vec<u32> {
        (0..g.num_nodes() as u32).flat_map(|v| g.neighbors(v).iter().copied().collect::<Vec<_>>()).collect()
    }

    /// Satellite property test: write shards → load → byte-identical
    /// `VertexCut` parts, id tables, DAR weights and node data, across the
    /// graph zoo and every partitioner.
    #[test]
    fn shard_roundtrip_is_byte_identical_across_zoo() {
        let dir = tmp_dir("zoo");
        for (gi, g) in graph_zoo(23).iter().enumerate() {
            let ds = dataset_for(g, 100 + gi as u64);
            for &name in ALGORITHMS.iter() {
                for &p in &[1usize, 3] {
                    let mut rng = Rng::new(7 * gi as u64 + p as u64);
                    let vc = VertexCut::create(g, p, algorithm(name).unwrap().as_ref(), &mut rng);
                    let weights = dar_weights(g, &vc, Reweighting::Dar);
                    let sub = dir.join(format!("{name}_{gi}_{p}"));
                    let stats = write_shards(&ds, &vc, &weights, 9, &sub).unwrap();
                    assert_eq!(stats.files.len(), p);
                    assert!(sub.join("manifest.json").exists());
                    let files = shard_files(&sub).unwrap();
                    assert_eq!(files.len(), p);
                    for (i, file) in files.iter().enumerate() {
                        let sh = Shard::read(file).unwrap();
                        let part = &vc.parts[i];
                        assert_eq!(sh.part_id, i);
                        assert_eq!(sh.num_parts, p);
                        assert_eq!(sh.global_ids, part.global_ids, "{name} g{gi} p{p} shard {i}");
                        assert_eq!(sh.local.edges(), part.local.edges());
                        assert_eq!(rows(&sh.local), rows(&part.local));
                        // DAR weights bit-exact.
                        let a: Vec<u32> = sh.dar.iter().map(|x| x.to_bits()).collect();
                        let b: Vec<u32> = weights[i].iter().map(|x| x.to_bits()).collect();
                        assert_eq!(a, b);
                        // Gathered node data matches the global arrays.
                        for (l, &gid) in part.global_ids.iter().enumerate() {
                            assert_eq!(
                                &sh.data.features[l * 6..(l + 1) * 6],
                                ds.data.feature(gid)
                            );
                            assert_eq!(sh.data.labels[l], ds.data.labels[gid as usize]);
                            assert_eq!(sh.data.split[l], ds.data.split[gid as usize]);
                        }
                    }
                }
            }
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    /// A shard tensorizes to the exact batch the in-process engine builds
    /// for the same partition — the worker-side half of the cross-process
    /// determinism contract.
    #[test]
    fn shard_tensorize_matches_tensorize_partition() {
        use crate::train::tensorize::tensorize_partition;
        let g = &graph_zoo(5)[2];
        let ds = dataset_for(g, 55);
        let mut rng = Rng::new(8);
        let vc = VertexCut::create(g, 4, algorithm("ne").unwrap().as_ref(), &mut rng);
        let weights = dar_weights(g, &vc, Reweighting::Dar);
        let dir = tmp_dir("tensorize");
        write_shards(&ds, &vc, &weights, 3, &dir).unwrap();
        for (i, file) in shard_files(&dir).unwrap().iter().enumerate() {
            let sh = Shard::read(file).unwrap();
            let (n_pad, e_pad) = (256, 1024);
            let a = sh.tensorize(n_pad, e_pad).unwrap();
            let b = tensorize_partition(&vc.parts[i], &ds.data, &weights[i], n_pad, e_pad).unwrap();
            assert_eq!(a.n_used, b.n_used);
            assert_eq!(a.e_used, b.e_used);
            assert_eq!(a.local_train_weight, b.local_train_weight);
            assert_eq!(a.tensors.len(), b.tensors.len());
            for (ti, (x, y)) in a.tensors.iter().zip(&b.tensors).enumerate() {
                assert_eq!(x, y, "tensor {ti} of shard {i}");
            }
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    /// Satellite: the mmap-backed load path is byte-identical to the
    /// streamed read — every array, the rebuilt CSR, and the tensorized
    /// batch — across the zoo and several partitioners.
    #[test]
    fn mmap_load_matches_streamed_read_byte_identically() {
        let dir = tmp_dir("mmapzoo");
        for (gi, g) in graph_zoo(31).iter().enumerate().take(6) {
            let ds = dataset_for(g, 500 + gi as u64);
            for &name in &["dbh", "ne"] {
                let p = 3usize;
                let mut rng = Rng::new(11 * gi as u64 + 1);
                let vc = VertexCut::create(g, p, algorithm(name).unwrap().as_ref(), &mut rng);
                let weights = dar_weights(g, &vc, Reweighting::Dar);
                let sub = dir.join(format!("{name}_{gi}"));
                write_shards(&ds, &vc, &weights, 9, &sub).unwrap();
                for file in shard_files(&sub).unwrap() {
                    let streamed = Shard::read(&file).unwrap();
                    let mapped = MappedShard::open(&file).unwrap();
                    #[cfg(all(unix, target_endian = "little", target_pointer_width = "64"))]
                    assert!(mapped.is_zero_copy(), "expected a real mapping on 64-bit unix/LE");
                    assert_eq!(mapped.part_id, streamed.part_id);
                    assert_eq!(mapped.num_parts, streamed.num_parts);
                    assert_eq!(mapped.model, streamed.model);
                    assert_eq!(mapped.seed, streamed.seed);
                    assert_eq!(mapped.global_ids(), &streamed.global_ids[..]);
                    assert_eq!(mapped.labels(), &streamed.data.labels[..]);
                    assert_eq!(mapped.split(), &streamed.data.split[..]);
                    let b = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
                    assert_eq!(b(mapped.dar()), b(&streamed.dar));
                    assert_eq!(b(mapped.features()), b(&streamed.data.features));
                    assert_eq!(mapped.local.edges(), streamed.local.edges());
                    assert_eq!(rows(&mapped.local), rows(&streamed.local));
                    // Materialized and tensorized forms agree exactly too.
                    let owned = mapped.to_shard();
                    assert_eq!(owned.global_ids, streamed.global_ids);
                    let (n_pad, e_pad) = (256, 2048);
                    let ta = mapped.tensorize(n_pad, e_pad).unwrap();
                    let tb = streamed.tensorize(n_pad, e_pad).unwrap();
                    assert_eq!(ta.tensors, tb.tensors);
                    assert_eq!(ta.local_train_weight, tb.local_train_weight);
                }
            }
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn mmap_load_rejects_corrupt_files() {
        let dir = tmp_dir("mmapbad");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("shard_0000.bin");
        std::fs::write(&p, b"COFREEG1........").unwrap();
        let err = MappedShard::open(&p).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("COFREESH") && msg.contains("COFREEG1"), "{msg}");
        // Truncated mid-array: write a valid shard then chop it.
        let g = &graph_zoo(5)[2];
        let ds = dataset_for(g, 77);
        let mut rng = Rng::new(3);
        let vc = VertexCut::create(g, 2, algorithm("dbh").unwrap().as_ref(), &mut rng);
        let weights = dar_weights(g, &vc, Reweighting::Dar);
        let sub = dir.join("ok");
        write_shards(&ds, &vc, &weights, 1, &sub).unwrap();
        let file = &shard_files(&sub).unwrap()[0];
        let bytes = std::fs::read(file).unwrap();
        let cut = dir.join("shard_cut.bin");
        std::fs::write(&cut, &bytes[..bytes.len() - 7]).unwrap();
        assert!(MappedShard::open(&cut).is_err(), "truncated shard must not load");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn shard_rejects_wrong_magic_with_found_vs_expected() {
        let dir = tmp_dir("badmagic");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("shard_0000.bin");
        std::fs::write(&p, b"COFREEG1........").unwrap();
        let err = Shard::read(&p).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("COFREESH") && msg.contains("COFREEG1"), "{msg}");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn shard_files_requires_shards() {
        let dir = tmp_dir("empty");
        std::fs::create_dir_all(&dir).unwrap();
        assert!(shard_files(&dir).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
