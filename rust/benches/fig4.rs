//! Bench harness: regenerates the paper's fig4 (see coordinator::experiments).
//! Run: `cargo bench --bench fig4` (COFREE_QUICK=1 for a fast smoke pass).

use cofree_gnn::coordinator::experiments::{run, ExpOptions};

fn main() {
    let opts = ExpOptions::default();
    match run("fig4", &opts) {
        Ok(report) => println!("{report}"),
        Err(e) => {
            eprintln!("fig4 failed: {e:#}");
            std::process::exit(1);
        }
    }
}
