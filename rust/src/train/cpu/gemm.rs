//! Cache-blocked, rayon-parallel f32 matrix kernels for the native backend.
//!
//! Shapes here are small-to-medium (`n_pad` rows × feature/hidden columns),
//! so the kernels optimize for the things that matter at that scale: B-row
//! reuse (a 4-row micro-kernel loads each row of `b` once per four rows of
//! `a`, quadrupling arithmetic intensity over the naive i-k-j loop),
//! k-blocking to keep the active slice of `b` in L1/L2, and row-block
//! parallelism via rayon.
//!
//! **Determinism:** every kernel accumulates each output element in a fixed
//! ascending-`k` order and parallelizes over disjoint row blocks of fixed
//! size, so results are bit-identical for any rayon pool size. `matmul` /
//! `matmul_acc` also preserve the exact floating-point summation order of
//! the naive `i-k-j` loop (ascending `k` per output element), which keeps
//! the fast forward bit-compatible with `train::reference::forward`'s
//! per-element sums.

use rayon::prelude::*;

/// Rows per rayon work unit. Fixed (not thread-count-derived) so chunk
/// boundaries — and therefore results — do not depend on the pool size.
const ROW_CHUNK: usize = 64;
/// K-blocking depth: `KC` rows of `b` (`KC × n` floats) stay hot per pass.
const KC: usize = 256;

/// `c = a @ b` with `a: [m, k]`, `b: [k, n]`, `c: [m, n]`, all row-major.
pub fn matmul(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    c.fill(0.0);
    matmul_acc(a, b, c, m, k, n);
}

/// `c += a @ b` (same shapes as [`matmul`]).
pub fn matmul_acc(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    c.par_chunks_mut(ROW_CHUNK * n)
        .zip(a.par_chunks(ROW_CHUNK * k))
        .for_each(|(c_blk, a_blk)| {
            let rows = c_blk.len() / n;
            debug_assert_eq!(rows * k, a_blk.len());
            block_acc(a_blk, b, c_blk, rows, k, n);
        });
}

/// Column-tile width of the register micro-kernel: 4 rows × `JT` columns of
/// accumulators (32 scalars) live in SIMD registers across the whole k
/// sweep, so `c` is touched once per tile instead of once per `k` step.
const JT: usize = 8;

/// Serial row-block kernel: 4 rows of `a` at a time, `JT`-wide register
/// accumulator tiles, `KC`-deep k blocks. Per output element the products
/// accumulate in ascending-`k` order, exactly like the naive loop.
fn block_acc(a: &[f32], b: &[f32], c: &mut [f32], rows: usize, k: usize, n: usize) {
    let mut k0 = 0;
    while k0 < k {
        let k1 = (k0 + KC).min(k);
        let mut i = 0;
        while i + 4 <= rows {
            let a0 = &a[i * k..(i + 1) * k];
            let a1 = &a[(i + 1) * k..(i + 2) * k];
            let a2 = &a[(i + 2) * k..(i + 3) * k];
            let a3 = &a[(i + 3) * k..(i + 4) * k];
            let mut j = 0;
            while j + JT <= n {
                let mut acc = [[0f32; JT]; 4];
                for (r, accr) in acc.iter_mut().enumerate() {
                    let base = (i + r) * n + j;
                    accr.copy_from_slice(&c[base..base + JT]);
                }
                for kk in k0..k1 {
                    let xs = [a0[kk], a1[kk], a2[kk], a3[kk]];
                    let bt = &b[kk * n + j..kk * n + j + JT];
                    for (r, accr) in acc.iter_mut().enumerate() {
                        let x = xs[r];
                        for (av, &bv) in accr.iter_mut().zip(bt.iter()) {
                            *av += x * bv;
                        }
                    }
                }
                for (r, accr) in acc.iter().enumerate() {
                    let base = (i + r) * n + j;
                    c[base..base + JT].copy_from_slice(accr);
                }
                j += JT;
            }
            if j < n {
                // Column tail (< JT columns): per-element accumulation in
                // the same ascending-k order.
                for kk in k0..k1 {
                    let xs = [a0[kk], a1[kk], a2[kk], a3[kk]];
                    let brow = &b[kk * n..(kk + 1) * n];
                    for (r, &x) in xs.iter().enumerate() {
                        if x == 0.0 {
                            continue;
                        }
                        let crow = &mut c[(i + r) * n..(i + r + 1) * n];
                        for jj in j..n {
                            crow[jj] += x * brow[jj];
                        }
                    }
                }
            }
            i += 4;
        }
        // Row tail (< 4 rows).
        while i < rows {
            let crow = &mut c[i * n..(i + 1) * n];
            let arow = &a[i * k..(i + 1) * k];
            for kk in k0..k1 {
                let x = arow[kk];
                if x == 0.0 {
                    continue;
                }
                let brow = &b[kk * n..kk * n + n];
                for (cv, &bv) in crow.iter_mut().zip(brow.iter()) {
                    *cv += x * bv;
                }
            }
            i += 1;
        }
        k0 = k1;
    }
}

/// `c = aᵀ @ b` with `a: [m, k]`, `b: [m, n]`, `c: [k, n]` — the
/// weight-gradient shape (`dW = hᵀ @ dpre`). Parallel over the `k` output
/// rows; each row sums over `i` in fixed ascending order.
pub fn matmul_tn(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), m * n);
    debug_assert_eq!(c.len(), k * n);
    if k == 0 || n == 0 {
        return;
    }
    c.par_chunks_mut(n).enumerate().for_each(|(kk, crow)| {
        crow.fill(0.0);
        for i in 0..m {
            let x = a[i * k + kk];
            if x != 0.0 {
                let brow = &b[i * n..i * n + n];
                for (j, &bv) in brow.iter().enumerate() {
                    crow[j] += x * bv;
                }
            }
        }
    });
}

/// `c = a @ bᵀ` with `a: [m, n]`, `b: [p, n]`, `c: [m, p]` — the
/// input-gradient shape (`dh = dout @ Uᵀ`). Row-parallel; each output
/// element is one contiguous-row dot product.
pub fn matmul_nt(a: &[f32], b: &[f32], c: &mut [f32], m: usize, n: usize, p: usize) {
    debug_assert_eq!(a.len(), m * n);
    debug_assert_eq!(b.len(), p * n);
    debug_assert_eq!(c.len(), m * p);
    if m == 0 || p == 0 {
        return;
    }
    if n == 0 {
        c.fill(0.0);
        return;
    }
    c.par_chunks_mut(p).zip(a.par_chunks(n)).for_each(|(crow, arow)| {
        for (kk, cv) in crow.iter_mut().enumerate() {
            let brow = &b[kk * n..kk * n + n];
            let mut s = 0.0f32;
            for (j, &av) in arow.iter().enumerate() {
                s += av * brow[j];
            }
            *cv = s;
        }
    });
}

/// Broadcast a length-`n` row into every row of `c` (bias init before the
/// accumulating matmuls — matches the reference's `out[i][j] = c[j] + …`
/// summation order).
pub fn broadcast_rows(row: &[f32], c: &mut [f32], n: usize) {
    debug_assert_eq!(row.len(), n);
    debug_assert_eq!(c.len() % n, 0);
    c.par_chunks_mut(n).for_each(|r| r.copy_from_slice(row));
}

/// Fused `c[i][j] = relu(c[i][j] + bias[j])` over rows (matches the
/// reference's `(Σ products) + b` order, *then* ReLU).
pub fn bias_relu_rows(c: &mut [f32], bias: &[f32], n: usize) {
    debug_assert_eq!(bias.len(), n);
    debug_assert_eq!(c.len() % n, 0);
    c.par_chunks_mut(n).for_each(|row| {
        for (j, x) in row.iter_mut().enumerate() {
            let v = *x + bias[j];
            *x = if v > 0.0 { v } else { 0.0 };
        }
    });
}

/// Column sums: `out[j] = Σ_i a[i][j]` (`a: [m, n]`) — the bias-gradient
/// reduction. Sequential ascending-`i`, deterministic by construction.
pub fn col_sums(a: &[f32], m: usize, n: usize, out: &mut [f32]) {
    debug_assert_eq!(a.len(), m * n);
    debug_assert_eq!(out.len(), n);
    out.fill(0.0);
    for i in 0..m {
        let arow = &a[i * n..i * n + n];
        for (j, &v) in arow.iter().enumerate() {
            out[j] += v;
        }
    }
}

/// Elementwise `c += other`.
pub fn add_assign(c: &mut [f32], other: &[f32]) {
    debug_assert_eq!(c.len(), other.len());
    c.par_chunks_mut(4096).zip(other.par_chunks(4096)).for_each(|(cb, ob)| {
        for (x, &y) in cb.iter_mut().zip(ob.iter()) {
            *x += y;
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_mat(rng: &mut Rng, len: usize) -> Vec<f32> {
        (0..len).map(|_| rng.f32() * 2.0 - 1.0).collect()
    }

    fn naive(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut c = vec![0f32; m * n];
        for i in 0..m {
            for kk in 0..k {
                let x = a[i * k + kk];
                if x != 0.0 {
                    for j in 0..n {
                        c[i * n + j] += x * b[kk * n + j];
                    }
                }
            }
        }
        c
    }

    fn assert_close(got: &[f32], want: &[f32], tol: f32) {
        assert_eq!(got.len(), want.len());
        for (i, (&g, &w)) in got.iter().zip(want.iter()).enumerate() {
            assert!(
                (g - w).abs() <= tol * (1.0 + w.abs()),
                "elem {i}: got {g}, want {w}"
            );
        }
    }

    #[test]
    fn matmul_matches_naive_on_odd_shapes() {
        let mut rng = Rng::new(1);
        // Shapes straddling the MR=4, ROW_CHUNK=64 and KC=256 boundaries.
        for &(m, k, n) in &[
            (1usize, 1usize, 1usize),
            (3, 5, 7),
            (4, 8, 16),
            (65, 300, 9),
            (130, 257, 33),
            (7, 1, 4),
        ] {
            let a = rand_mat(&mut rng, m * k);
            let b = rand_mat(&mut rng, k * n);
            let mut c = vec![9.9f32; m * n];
            matmul(&a, &b, &mut c, m, k, n);
            assert_close(&c, &naive(&a, &b, m, k, n), 1e-5);
        }
    }

    #[test]
    fn matmul_acc_accumulates() {
        let mut rng = Rng::new(2);
        let (m, k, n) = (10usize, 6usize, 5usize);
        let a = rand_mat(&mut rng, m * k);
        let b = rand_mat(&mut rng, k * n);
        let mut c = vec![1.0f32; m * n];
        matmul_acc(&a, &b, &mut c, m, k, n);
        let mut want = naive(&a, &b, m, k, n);
        want.iter_mut().for_each(|x| *x += 1.0);
        assert_close(&c, &want, 1e-5);
    }

    #[test]
    fn matmul_tn_matches_transposed_naive() {
        let mut rng = Rng::new(3);
        let (m, k, n) = (33usize, 7usize, 11usize);
        let a = rand_mat(&mut rng, m * k);
        let b = rand_mat(&mut rng, m * n);
        let mut c = vec![0f32; k * n];
        matmul_tn(&a, &b, &mut c, m, k, n);
        // aᵀ laid out explicitly, then naive.
        let mut at = vec![0f32; k * m];
        for i in 0..m {
            for kk in 0..k {
                at[kk * m + i] = a[i * k + kk];
            }
        }
        assert_close(&c, &naive(&at, &b, k, m, n), 1e-5);
    }

    #[test]
    fn matmul_nt_matches_transposed_naive() {
        let mut rng = Rng::new(4);
        let (m, n, p) = (9usize, 13usize, 6usize);
        let a = rand_mat(&mut rng, m * n);
        let b = rand_mat(&mut rng, p * n);
        let mut c = vec![0f32; m * p];
        matmul_nt(&a, &b, &mut c, m, n, p);
        let mut bt = vec![0f32; n * p];
        for kk in 0..p {
            for j in 0..n {
                bt[j * p + kk] = b[kk * n + j];
            }
        }
        assert_close(&c, &naive(&a, &bt, m, n, p), 1e-5);
    }

    #[test]
    fn kernels_bit_identical_across_thread_counts() {
        let mut rng = Rng::new(5);
        let (m, k, n) = (200usize, 130usize, 40usize);
        let a = rand_mat(&mut rng, m * k);
        let b = rand_mat(&mut rng, k * n);
        let mut base = vec![0f32; m * n];
        matmul(&a, &b, &mut base, m, k, n);
        for threads in [1usize, 2, 8] {
            let pool = rayon::ThreadPoolBuilder::new().num_threads(threads).build().unwrap();
            let mut c = vec![0f32; m * n];
            pool.install(|| matmul(&a, &b, &mut c, m, k, n));
            assert_eq!(c, base, "matmul differs at {threads} threads");
            let bb = rand_mat(&mut Rng::new(6), m * n);
            let mut t = vec![0f32; k * n];
            let mut t_base = vec![0f32; k * n];
            matmul_tn(&a, &bb, &mut t_base, m, k, n);
            pool.install(|| matmul_tn(&a, &bb, &mut t, m, k, n));
            assert_eq!(t, t_base, "matmul_tn differs at {threads} threads");
        }
    }

    #[test]
    fn bias_relu_and_colsums() {
        let c0 = vec![1.0f32, -2.0, 0.5, -0.1, 3.0, 0.0];
        let bias = vec![0.1f32, 0.2];
        let mut c = c0.clone();
        bias_relu_rows(&mut c, &bias, 2);
        assert_close(&c, &[1.1, 0.0, 0.6, 0.1, 3.1, 0.2], 1e-6);
        let mut sums = vec![0f32; 2];
        col_sums(&c0, 3, 2, &mut sums);
        assert!((sums[0] - 4.5).abs() < 1e-6);
        assert!((sums[1] + 2.1).abs() < 1e-6);
    }

    #[test]
    fn broadcast_and_add_assign() {
        let mut c = vec![0f32; 6];
        broadcast_rows(&[1.0, 2.0], &mut c, 2);
        assert_eq!(c, vec![1.0, 2.0, 1.0, 2.0, 1.0, 2.0]);
        add_assign(&mut c, &[1.0; 6]);
        assert_eq!(c, vec![2.0, 3.0, 2.0, 3.0, 2.0, 3.0]);
    }
}
