//! Chung–Lu random graph with a prescribed expected degree sequence, plus a
//! power-law degree-sequence sampler.
//!
//! This is the generator we use when an experiment needs *exact control over
//! the degree distribution* (Theorem 4.2's replication-imbalance bound is a
//! function of `min_j D(v_j)` and `max_j D(v_j)` only).

use crate::graph::builder::GraphBuilder;
use crate::graph::csr::Graph;
use crate::util::rng::Rng;

/// Sample `n` degrees from a truncated discrete power law
/// `P(d) ∝ d^{-gamma}` on `[d_min, d_max]` via inverse-CDF on the continuous
/// Pareto and rounding.
pub fn power_law_degrees(n: usize, gamma: f64, d_min: u32, d_max: u32, rng: &mut Rng) -> Vec<u32> {
    assert!(gamma > 1.0, "power-law exponent must exceed 1");
    assert!(d_min >= 1 && d_max >= d_min);
    let (a, b) = (d_min as f64, d_max as f64 + 1.0);
    let one_m_g = 1.0 - gamma;
    let (pa, pb) = (a.powf(one_m_g), b.powf(one_m_g));
    (0..n)
        .map(|_| {
            let u = rng.f64();
            let x = (pa + u * (pb - pa)).powf(1.0 / one_m_g);
            (x.floor() as u32).clamp(d_min, d_max)
        })
        .collect()
}

/// Sample the raw Chung–Lu endpoint pairs (`Σw / 2` draws from the weight
/// distribution; may contain self-loops and duplicates). Exposed separately
/// so `bench_partition` can time graph construction on the raw stream.
pub fn chung_lu_pairs(weights: &[u32], rng: &mut Rng) -> Vec<(u32, u32)> {
    let n = weights.len();
    let total: u64 = weights.iter().map(|&w| w as u64).sum();
    // Alias-free sampling: cumulative table + binary search. Fine at our
    // scales (few hundred thousand draws of log n cost).
    let mut cum: Vec<u64> = Vec::with_capacity(n);
    let mut acc = 0u64;
    for &w in weights {
        acc += w as u64;
        cum.push(acc);
    }
    let draw = |rng: &mut Rng, cum: &[u64]| -> u32 {
        let t = (rng.next_u64() as u128 * acc as u128 >> 64) as u64;
        cum.partition_point(|&c| c <= t) as u32
    };
    let m = (total / 2) as usize;
    let mut pairs = Vec::with_capacity(m);
    for _ in 0..m {
        let u = draw(rng, &cum);
        let v = draw(rng, &cum);
        pairs.push((u, v));
    }
    pairs
}

/// Chung–Lu: connect `u, v` with probability `≈ w_u w_v / Σw`, realized by
/// sampling `Σw / 2` endpoint pairs from the weight distribution. Expected
/// degrees match `weights` up to collision/dedup losses.
pub fn chung_lu(weights: &[u32], rng: &mut Rng) -> Graph {
    let n = weights.len();
    GraphBuilder::new(n).edges(&chung_lu_pairs(weights, rng)).build()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn power_law_respects_bounds() {
        let mut rng = Rng::new(1);
        let d = power_law_degrees(10_000, 2.2, 3, 500, &mut rng);
        assert!(d.iter().all(|&x| (3..=500).contains(&x)));
        // Heavy tail: some degree above 50 must appear, and the bulk must be
        // near d_min.
        assert!(d.iter().any(|&x| x > 50));
        let small = d.iter().filter(|&&x| x <= 6).count();
        assert!(small > 5_000, "bulk at small degrees, got {small}");
    }

    #[test]
    fn chung_lu_mean_degree_tracks_weights() {
        let mut rng = Rng::new(2);
        let w = power_law_degrees(2000, 2.3, 4, 100, &mut rng);
        let expected_avg = w.iter().map(|&x| x as f64).sum::<f64>() / w.len() as f64;
        let g = chung_lu(&w, &mut rng);
        let got = g.avg_degree();
        // Collisions + dedup shrink things; allow generous tolerance but the
        // order of magnitude must match.
        assert!(got > 0.5 * expected_avg && got < 1.2 * expected_avg, "got={got} want≈{expected_avg}");
        // Hubs exist.
        assert!(g.max_degree() > 3 * got as u32);
        g.check_invariants().unwrap();
    }
}
