//! Bounded-memory external sort of the canonical edge stream.
//!
//! [`ExternalSorter`] accepts raw endpoint pairs (self-loops, duplicates,
//! either orientation), canonicalizes them (`u < v`, loops dropped), and
//! holds at most `chunk_cap` edges in memory. Full chunks are rayon-sorted,
//! deduped and spilled to CRC-trailed run files in a [`ScratchDir`]; when
//! the run count exceeds the merge fan-in, whole passes of `fan_in`-way
//! merges collapse them. The final [`ExternalSorter::stream`] is a k-way
//! **loser-tree** merge with on-the-fly dedup that yields exactly the
//! sorted, unique, self-loop-free canonical edge list
//! [`GraphBuilder::build`](crate::graph::GraphBuilder::build) produces —
//! and it is replayable: every call re-merges the persisted runs, so the
//! multi-pass pipeline (degree table → membership → materialize) never
//! needs the stream in memory.
//!
//! Run file format (little-endian): magic `COFRERUN` | u32 version |
//! u64 count of u32 words (`2·edges`) | the flattened sorted pairs |
//! trailer u32 CRC-32C over every preceding byte. Runs are written through
//! the PR 7 durable-write helpers (tmp sibling → fsync → rename), and the
//! trailer is verified as each run is re-read, so a torn or bit-flipped
//! spill surfaces as a structured error instead of a silently wrong store.

use crate::obs::metrics;
use crate::util::binio;
use crate::util::hash::{Crc32c, HashingWriter};
use anyhow::{ensure, Context, Result};
use rayon::prelude::*;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::{Path, PathBuf};

pub const RUN_MAGIC: &[u8; 8] = b"COFRERUN";
pub const RUN_VERSION: u32 = 1;

/// Default number of runs merged at once. 64 read buffers of 32 KiB keep
/// merge memory at 2 MiB; with chunk sizes in the tens of MiB a single
/// intermediate pass already covers multi-TiB inputs.
pub const DEFAULT_FAN_IN: usize = 64;

const READ_BUF: usize = 32 * 1024;

/// The registered spill directory: every intermediate file of a streaming
/// ingest lives under `<store>/.ingest-scratch`, which is wiped when a new
/// ingest starts (clearing debris from any interrupted predecessor) and
/// removed again on successful close — `cofree shard` never strands stray
/// tmp siblings between spill runs.
pub struct ScratchDir {
    dir: PathBuf,
    armed: bool,
}

/// Directory name of the ingest scratch space inside a store.
pub const SCRATCH_DIR_NAME: &str = ".ingest-scratch";

impl ScratchDir {
    /// Create (and first clean) the scratch dir under `parent`.
    pub fn create(parent: &Path) -> Result<ScratchDir> {
        let dir = parent.join(SCRATCH_DIR_NAME);
        if dir.exists() {
            std::fs::remove_dir_all(&dir)
                .with_context(|| format!("cleaning stale ingest scratch {dir:?}"))?;
        }
        std::fs::create_dir_all(&dir).with_context(|| format!("create {dir:?}"))?;
        Ok(ScratchDir { dir, armed: true })
    }

    /// Path of a file inside the scratch dir.
    pub fn file(&self, name: &str) -> PathBuf {
        self.dir.join(name)
    }

    /// Remove the scratch dir (the successful-close half of the hygiene
    /// contract).
    pub fn close(mut self) -> Result<()> {
        self.armed = false;
        std::fs::remove_dir_all(&self.dir)
            .with_context(|| format!("removing ingest scratch {:?}", self.dir))
    }
}

impl Drop for ScratchDir {
    fn drop(&mut self) {
        // Error paths: best-effort cleanup; anything left is wiped by the
        // next ingest's startup clean.
        if self.armed {
            let _ = std::fs::remove_dir_all(&self.dir);
        }
    }
}

/// Spill one sorted, deduped chunk as a run file. Returns bytes written.
fn write_run(path: &Path, edges: &[(u32, u32)]) -> Result<u64> {
    let tmp = binio::tmp_sibling(path);
    let guard = binio::TmpGuard::new(tmp.clone());
    let f = std::fs::File::create(&tmp).with_context(|| format!("create {tmp:?}"))?;
    let mut w = HashingWriter::new(BufWriter::new(f));
    binio::write_magic(&mut w, RUN_MAGIC)?;
    binio::write_version(&mut w, RUN_VERSION)?;
    binio::write_u64(&mut w, edges.len() as u64 * 2)?;
    for &(u, v) in edges {
        binio::write_u32(&mut w, u)?;
        binio::write_u32(&mut w, v)?;
    }
    let digest = w.digest();
    binio::write_u32(&mut w, digest)?;
    let bytes = w.written();
    let mut bw = w.into_inner();
    bw.flush().with_context(|| format!("flushing {tmp:?}"))?;
    bw.get_ref().sync_all().with_context(|| format!("fsyncing {tmp:?}"))?;
    binio::commit_replace(&tmp, path)?;
    guard.disarm();
    Ok(bytes)
}

/// Streaming reader over one run file: fixed `READ_BUF` buffer, CRC
/// accumulated as the pairs are consumed and checked against the trailer
/// at exhaustion.
struct RunReader {
    r: BufReader<std::fs::File>,
    crc: Crc32c,
    path: PathBuf,
    /// Pairs left to read.
    remaining: u64,
}

impl RunReader {
    fn open(path: &Path) -> Result<RunReader> {
        let f = std::fs::File::open(path).with_context(|| format!("open spill run {path:?}"))?;
        let mut r = BufReader::with_capacity(READ_BUF, f);
        let mut crc = Crc32c::new();
        let mut header = [0u8; 8 + 4 + 8];
        r.read_exact(&mut header)
            .with_context(|| format!("truncated spill run {path:?}: header missing"))?;
        crc.update(&header);
        ensure!(
            &header[..8] == RUN_MAGIC,
            "bad spill run magic in {path:?}: found {:02x?}, expected {RUN_MAGIC:02x?}",
            &header[..8]
        );
        let version = u32::from_le_bytes(header[8..12].try_into().unwrap());
        ensure!(version == RUN_VERSION, "unsupported spill run version {version} in {path:?}");
        let words = u64::from_le_bytes(header[12..20].try_into().unwrap());
        ensure!(words % 2 == 0, "corrupt spill run {path:?}: odd endpoint count {words}");
        Ok(RunReader { r, crc, path: path.to_path_buf(), remaining: words / 2 })
    }

    /// Next pair, or `None` at the (trailer-verified) end of the run.
    fn next(&mut self) -> Result<Option<(u32, u32)>> {
        if self.remaining == 0 {
            let want = self.crc.finish();
            let mut trailer = [0u8; 4];
            self.r
                .read_exact(&mut trailer)
                .with_context(|| format!("truncated spill run {:?}: trailer missing", self.path))?;
            let got = u32::from_le_bytes(trailer);
            ensure!(
                got == want,
                "spill run digest mismatch in {:?}: stored {got:#010x}, computed {want:#010x} \
                 — the scratch bytes are corrupt",
                self.path
            );
            return Ok(None);
        }
        let mut buf = [0u8; 8];
        self.r.read_exact(&mut buf).with_context(|| {
            format!(
                "truncated spill run {:?}: {} pair(s) missing",
                self.path, self.remaining
            )
        })?;
        self.crc.update(&buf);
        self.remaining -= 1;
        Ok(Some((
            u32::from_le_bytes(buf[..4].try_into().unwrap()),
            u32::from_le_bytes(buf[4..].try_into().unwrap()),
        )))
    }
}

/// A k-way loser-tree merge over sorted runs, with on-the-fly dedup.
///
/// Classic tournament bookkeeping: `tree[1..k]` stores the *loser* of the
/// match at each internal node, `winner` the champion; replacing the
/// champion's head replays only its root path (`O(log k)` comparisons per
/// edge). Ties break toward the lower run index, so the merge is a pure
/// function of the run contents.
pub struct MergedStream {
    sources: Vec<RunReader>,
    heads: Vec<Option<(u32, u32)>>,
    /// Loser at each internal node, `1..k`; `tree[0]` is unused.
    tree: Vec<usize>,
    winner: usize,
    last: Option<(u32, u32)>,
    done: bool,
}

impl MergedStream {
    fn new(mut sources: Vec<RunReader>) -> Result<MergedStream> {
        let k = sources.len();
        let mut heads = Vec::with_capacity(k);
        for s in sources.iter_mut() {
            heads.push(s.next()?);
        }
        if k == 0 {
            return Ok(MergedStream {
                sources,
                heads,
                tree: Vec::new(),
                winner: 0,
                last: None,
                done: true,
            });
        }
        // Build bottom-up: node t (1..k) plays the winners of its children;
        // nodes >= k are the leaves (source index node - k).
        let mut winners = vec![0usize; 2 * k];
        for (i, w) in winners.iter_mut().enumerate().skip(k) {
            *w = i - k;
        }
        let mut tree = vec![0usize; k.max(1)];
        for t in (1..k).rev() {
            let (a, b) = (winners[2 * t], winners[2 * t + 1]);
            let (win, lose) = if Self::beats(&heads, a, b) { (a, b) } else { (b, a) };
            winners[t] = win;
            tree[t] = lose;
        }
        let winner = winners[1.min(2 * k - 1)];
        Ok(MergedStream { sources, heads, tree, winner, last: None, done: false })
    }

    /// Does source `a` outrank source `b`? Exhausted sources (`None`) rank
    /// last; equal keys go to the lower run index.
    #[inline]
    fn beats(heads: &[Option<(u32, u32)>], a: usize, b: usize) -> bool {
        match (&heads[a], &heads[b]) {
            (Some(x), Some(y)) => (x, a) < (y, b),
            (Some(_), None) => true,
            (None, Some(_)) => false,
            (None, None) => a < b,
        }
    }

    /// Pop the globally smallest pair (duplicates across and within runs
    /// already removed), or `None` at end of stream.
    pub fn next(&mut self) -> Result<Option<(u32, u32)>> {
        loop {
            if self.done {
                return Ok(None);
            }
            let k = self.sources.len();
            let Some(pair) = self.heads[self.winner] else {
                self.done = true;
                return Ok(None);
            };
            // Advance the champion and replay its path to the root.
            self.heads[self.winner] = self.sources[self.winner].next()?;
            let mut carried = self.winner;
            let mut t = (self.winner + k) / 2;
            while t >= 1 {
                if Self::beats(&self.heads, self.tree[t], carried) {
                    std::mem::swap(&mut self.tree[t], &mut carried);
                }
                t /= 2;
            }
            self.winner = carried;
            if self.last != Some(pair) {
                self.last = Some(pair);
                return Ok(Some(pair));
            }
        }
    }
}

/// Bounded-memory external sorter for the canonical edge stream. See the
/// module docs for the spill/merge contract.
pub struct ExternalSorter {
    scratch: ScratchDir,
    chunk_cap: usize,
    fan_in: usize,
    buf: Vec<(u32, u32)>,
    runs: Vec<PathBuf>,
    next_run: u64,
    finished: bool,
    spill_bytes: u64,
    runs_spilled: usize,
    merge_passes: u32,
}

impl ExternalSorter {
    /// A sorter spilling at `chunk_cap` buffered edges, merging at most
    /// `fan_in` runs per pass. `chunk_cap ≥ 1` (pathological 1-edge chunks
    /// are exercised by the parity tests); `fan_in ≥ 2`.
    pub fn new(scratch: ScratchDir, chunk_cap: usize, fan_in: usize) -> Result<ExternalSorter> {
        ensure!(chunk_cap >= 1, "chunk capacity must be at least 1 edge");
        ensure!(fan_in >= 2, "merge fan-in must be at least 2");
        Ok(ExternalSorter {
            scratch,
            chunk_cap,
            fan_in,
            buf: Vec::with_capacity(chunk_cap.min(1 << 22)),
            runs: Vec::new(),
            next_run: 0,
            finished: false,
            spill_bytes: 0,
            runs_spilled: 0,
            merge_passes: 0,
        })
    }

    /// Accept one raw pair: self-loops are dropped, orientation is
    /// canonicalized, and a full chunk is sorted and spilled.
    #[inline]
    pub fn push(&mut self, u: u32, v: u32) -> Result<()> {
        if u == v {
            return Ok(());
        }
        self.buf.push(if u < v { (u, v) } else { (v, u) });
        if self.buf.len() >= self.chunk_cap {
            self.spill()?;
        }
        Ok(())
    }

    /// Sort + dedup the buffered chunk (rayon parallel sort, same
    /// `par_sort_unstable` + `dedup` as `GraphBuilder::build`) and spill it.
    fn spill(&mut self) -> Result<()> {
        if self.buf.is_empty() {
            return Ok(());
        }
        self.buf.par_sort_unstable();
        self.buf.dedup();
        let path = self.scratch.file(&format!("run_{:06}.bin", self.next_run));
        self.next_run += 1;
        let bytes = write_run(&path, &self.buf)?;
        self.spill_bytes += bytes;
        self.runs_spilled += 1;
        metrics::counter("ingest.spill_bytes").add(bytes);
        metrics::counter("ingest.runs_spilled").inc();
        self.runs.push(path);
        self.buf.clear();
        Ok(())
    }

    /// Merge a group of runs into one new run (dedup preserved level by
    /// level), deleting the inputs. The run header carries an exact pair
    /// count, and cross-run dedup makes that count unknowable up front —
    /// so the group is merged twice: a counting pass, then the writing
    /// pass. Both are sequential reads through `fan_in` small buffers.
    fn merge_group(&mut self, group: &[PathBuf]) -> Result<PathBuf> {
        let open_all = |group: &[PathBuf]| -> Result<Vec<RunReader>> {
            group.iter().map(|p| RunReader::open(p)).collect()
        };
        let mut counter = MergedStream::new(open_all(group)?)?;
        let mut count = 0u64;
        while counter.next()?.is_some() {
            count += 1;
        }
        let mut stream = MergedStream::new(open_all(group)?)?;
        let out = self.scratch.file(&format!("run_{:06}.bin", self.next_run));
        self.next_run += 1;
        let tmp = binio::tmp_sibling(&out);
        let guard = binio::TmpGuard::new(tmp.clone());
        let f = std::fs::File::create(&tmp).with_context(|| format!("create {tmp:?}"))?;
        let mut w = HashingWriter::new(BufWriter::new(f));
        binio::write_magic(&mut w, RUN_MAGIC)?;
        binio::write_version(&mut w, RUN_VERSION)?;
        binio::write_u64(&mut w, count * 2)?;
        let mut written = 0u64;
        while let Some((u, v)) = stream.next()? {
            binio::write_u32(&mut w, u)?;
            binio::write_u32(&mut w, v)?;
            written += 1;
        }
        ensure!(written == count, "merge replay diverged: {written} pairs vs {count} counted");
        let digest = w.digest();
        binio::write_u32(&mut w, digest)?;
        let bytes = w.written();
        let mut bw = w.into_inner();
        bw.flush().with_context(|| format!("flushing {tmp:?}"))?;
        bw.get_ref().sync_all().with_context(|| format!("fsyncing {tmp:?}"))?;
        binio::commit_replace(&tmp, &out)?;
        guard.disarm();
        self.spill_bytes += bytes;
        metrics::counter("ingest.spill_bytes").add(bytes);
        for p in group {
            std::fs::remove_file(p).with_context(|| format!("removing merged run {p:?}"))?;
        }
        Ok(out)
    }

    /// Flush the tail chunk and collapse runs down to at most `fan_in`
    /// with whole multi-way merge passes.
    pub fn finish(&mut self) -> Result<()> {
        ensure!(!self.finished, "sorter already finished");
        self.spill()?;
        while self.runs.len() > self.fan_in {
            let groups: Vec<Vec<PathBuf>> =
                self.runs.chunks(self.fan_in).map(|c| c.to_vec()).collect();
            let mut next = Vec::with_capacity(groups.len());
            for group in &groups {
                if group.len() == 1 {
                    next.push(group[0].clone());
                } else {
                    next.push(self.merge_group(group)?);
                }
            }
            self.runs = next;
            self.merge_passes += 1;
            metrics::counter("ingest.merge_passes").inc();
        }
        // The final streaming merge counts as a pass too (it is re-run on
        // every replay, but the work shape is one pass over the data).
        if self.runs.len() > 1 {
            self.merge_passes += 1;
            metrics::counter("ingest.merge_passes").inc();
        }
        self.finished = true;
        Ok(())
    }

    /// Open a replayable merged view over the final runs: the canonical
    /// sorted, deduped, self-loop-free edge stream.
    pub fn stream(&self) -> Result<MergedStream> {
        ensure!(self.finished, "call finish() before stream()");
        let readers =
            self.runs.iter().map(|p| RunReader::open(p)).collect::<Result<Vec<_>>>()?;
        MergedStream::new(readers)
    }

    /// Total bytes spilled to scratch (initial runs + intermediate merges).
    pub fn spill_bytes(&self) -> u64 {
        self.spill_bytes
    }

    /// Number of initial runs spilled.
    pub fn runs_spilled(&self) -> usize {
        self.runs_spilled
    }

    /// Multi-way merge passes executed (intermediate collapses plus the
    /// final streaming merge when more than one run remains).
    pub fn merge_passes(&self) -> u32 {
        self.merge_passes
    }

    /// Remove the scratch dir (successful close).
    pub fn close(self) -> Result<()> {
        self.scratch.close()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;
    use crate::util::rng::Rng;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("cofree_extsort_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn drain(sorter: &ExternalSorter) -> Vec<(u32, u32)> {
        let mut s = sorter.stream().unwrap();
        let mut out = Vec::new();
        while let Some(e) = s.next().unwrap() {
            out.push(e);
        }
        out
    }

    /// The merged stream equals `GraphBuilder::build`'s canonical edge
    /// list for any chunk size — including pathological 1-edge chunks —
    /// and any fan-in (multi-pass merges included).
    #[test]
    fn matches_builder_across_chunk_sizes_and_fan_in() {
        let dir = tmpdir("parity");
        let mut rng = Rng::new(11);
        let n = 120usize;
        let mut pairs = Vec::new();
        for _ in 0..800 {
            // Raw stream with self-loops and duplicates in both orientations.
            pairs.push((rng.below(n) as u32, rng.below(n) as u32));
        }
        let want = GraphBuilder::new(n).edges(&pairs).build().edges().to_vec();
        for (chunk, fan_in) in [(1usize, 2usize), (7, 2), (64, 3), (100_000, 64), (333, 4)] {
            let scratch = ScratchDir::create(&dir).unwrap();
            let mut sorter = ExternalSorter::new(scratch, chunk, fan_in).unwrap();
            for &(u, v) in &pairs {
                sorter.push(u, v).unwrap();
            }
            sorter.finish().unwrap();
            assert_eq!(drain(&sorter), want, "chunk={chunk} fan_in={fan_in}");
            // Replayable: a second stream yields the same list.
            assert_eq!(drain(&sorter), want, "replay chunk={chunk}");
            if chunk == 1 {
                // ~800 one-edge runs through fan-in 2 forces many passes.
                assert!(sorter.merge_passes() > 5, "passes={}", sorter.merge_passes());
            }
            sorter.close().unwrap();
        }
        assert!(!dir.join(SCRATCH_DIR_NAME).exists(), "scratch not cleaned");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn empty_and_loop_only_streams() {
        let dir = tmpdir("empty");
        let scratch = ScratchDir::create(&dir).unwrap();
        let mut sorter = ExternalSorter::new(scratch, 8, 2).unwrap();
        sorter.push(3, 3).unwrap(); // self-loop only
        sorter.finish().unwrap();
        assert_eq!(drain(&sorter), vec![]);
        sorter.close().unwrap();
        std::fs::remove_dir_all(&dir).unwrap();
    }

    /// Startup hygiene: creating the scratch dir wipes debris a crashed
    /// predecessor left behind (the stray-tmp-siblings fix).
    #[test]
    fn startup_clean_removes_stale_spills() {
        let dir = tmpdir("stale");
        let stale = dir.join(SCRATCH_DIR_NAME);
        std::fs::create_dir_all(&stale).unwrap();
        std::fs::write(stale.join("run_000042.bin.tmp"), b"debris").unwrap();
        std::fs::write(stale.join("run_000042.bin"), b"debris").unwrap();
        let scratch = ScratchDir::create(&dir).unwrap();
        assert!(!stale.join("run_000042.bin").exists());
        assert!(!stale.join("run_000042.bin.tmp").exists());
        scratch.close().unwrap();
        assert!(!stale.exists());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    /// A bit-flipped spill run is a structured error at merge time, not a
    /// silently wrong edge stream.
    #[test]
    fn corrupt_run_is_detected() {
        let dir = tmpdir("corrupt");
        let scratch = ScratchDir::create(&dir).unwrap();
        let run = scratch.file("run_000000.bin");
        let mut sorter = ExternalSorter::new(scratch, 4, 2).unwrap();
        for e in [(0u32, 1u32), (1, 2), (2, 3), (3, 4)] {
            sorter.push(e.0, e.1).unwrap();
        }
        sorter.finish().unwrap();
        crate::dist::fault::flip_file_bit(&run, 21, 2).unwrap();
        let mut s = sorter.stream().unwrap();
        let err = loop {
            match s.next() {
                Ok(Some(_)) => continue,
                Ok(None) => panic!("corruption not detected"),
                Err(e) => break e,
            }
        };
        assert!(format!("{err:#}").contains("digest mismatch"), "{err:#}");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    /// Truncated runs are named as truncation.
    #[test]
    fn truncated_run_is_detected() {
        let dir = tmpdir("trunc");
        let scratch = ScratchDir::create(&dir).unwrap();
        let run = scratch.file("run_000000.bin");
        let mut sorter = ExternalSorter::new(scratch, 8, 2).unwrap();
        for e in [(0u32, 1u32), (1, 2), (2, 3)] {
            sorter.push(e.0, e.1).unwrap();
        }
        sorter.finish().unwrap();
        let len = std::fs::metadata(&run).unwrap().len();
        crate::dist::fault::truncate_file(&run, len - 6).unwrap();
        let mut s = sorter.stream().unwrap();
        let err = loop {
            match s.next() {
                Ok(Some(_)) => continue,
                Ok(None) => panic!("truncation not detected"),
                Err(e) => break e,
            }
        };
        assert!(format!("{err:#}").contains("truncated spill run"), "{err:#}");
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
