//! Graph construction from arbitrary edge streams.
//!
//! All generators and loaders feed through [`GraphBuilder`], which
//! canonicalizes (u < v), strips self-loops, de-duplicates, and builds the
//! symmetric CSR. [`GraphBuilder::build`] is the parallel fast path: a rayon
//! sort of the canonical edge list followed by a counting-sort CSR fill that
//! never re-sorts adjacency rows. [`GraphBuilder::build_reference`] retains
//! the pre-optimization sequential construction; tests and the
//! `bench_partition` harness compare the two for byte-identical output.

use super::csr::Graph;
use rayon::prelude::*;

/// Accumulates edges, then builds a [`Graph`].
pub struct GraphBuilder {
    n: usize,
    edges: Vec<(u32, u32)>,
}

impl GraphBuilder {
    /// A builder for a graph with `n` nodes (ids `0..n`).
    pub fn new(n: usize) -> Self {
        assert!(n < u32::MAX as usize, "node ids must fit u32");
        GraphBuilder { n, edges: Vec::new() }
    }

    /// Add one undirected edge. Self-loops are silently dropped; duplicates
    /// (in either orientation) are removed at build time.
    #[inline]
    pub fn edge(&mut self, u: u32, v: u32) -> &mut Self {
        debug_assert!((u as usize) < self.n && (v as usize) < self.n);
        if u != v {
            self.edges.push(if u < v { (u, v) } else { (v, u) });
        }
        self
    }

    /// Add many edges.
    pub fn edges(mut self, es: &[(u32, u32)]) -> Self {
        self.edges.reserve(es.len());
        for &(u, v) in es {
            self.edge(u, v);
        }
        self
    }

    /// Number of (possibly duplicate) edges accumulated so far.
    pub fn pending(&self) -> usize {
        self.edges.len()
    }

    /// Finalize: parallel sort + dedup of the canonical edge list, then a
    /// counting-sort CSR fill with no per-row re-sort. Output is identical
    /// to [`GraphBuilder::build_reference`] (unstable sort of a list whose
    /// duplicates are equal is deterministic), for any rayon thread count.
    pub fn build(mut self) -> Graph {
        self.edges.par_sort_unstable();
        self.edges.dedup();
        Graph::from_sorted_edges(self.n, self.edges)
    }

    /// The pre-optimization sequential build: global sort + interleaved
    /// scatter + per-row sort. Kept as the oracle for the fast path; used by
    /// parity tests and as the "old" side of `bench_partition`.
    pub fn build_reference(mut self) -> Graph {
        self.edges.sort_unstable();
        self.edges.dedup();
        let n = self.n;
        let m = self.edges.len();
        // Counting pass.
        let mut deg = vec![0u32; n];
        for &(u, v) in &self.edges {
            deg[u as usize] += 1;
            deg[v as usize] += 1;
        }
        let mut offsets = vec![0u32; n + 1];
        for i in 0..n {
            offsets[i + 1] = offsets[i] + deg[i];
        }
        // Fill pass; because the canonical list is sorted, rows come out
        // sorted if we fill u-side in order and v-side via insertion cursor.
        let mut cursor: Vec<u32> = offsets[..n].to_vec();
        let mut targets = vec![0u32; 2 * m];
        for &(u, v) in &self.edges {
            targets[cursor[u as usize] as usize] = v;
            cursor[u as usize] += 1;
            targets[cursor[v as usize] as usize] = u;
            cursor[v as usize] += 1;
        }
        // Rows need a final sort: u-side entries are ascending but interleaved
        // with v-side backedges.
        for i in 0..n {
            targets[offsets[i] as usize..offsets[i + 1] as usize].sort_unstable();
        }
        Graph::from_parts(offsets, targets, self.edges)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dedup_and_canonicalize() {
        let g = GraphBuilder::new(3).edges(&[(0, 1), (1, 0), (0, 1), (2, 1)]).build();
        assert_eq!(g.num_edges(), 2);
        assert_eq!(g.edges(), &[(0, 1), (1, 2)]);
        g.check_invariants().unwrap();
    }

    #[test]
    fn self_loops_dropped() {
        let g = GraphBuilder::new(2).edges(&[(0, 0), (0, 1), (1, 1)]).build();
        assert_eq!(g.num_edges(), 1);
        g.check_invariants().unwrap();
    }

    #[test]
    fn empty_graph() {
        let g = GraphBuilder::new(0).edges(&[]).build();
        assert_eq!(g.num_nodes(), 0);
        assert_eq!(g.num_edges(), 0);
    }

    #[test]
    fn fast_build_matches_reference() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(77);
        for (n, m) in [(1usize, 0usize), (2, 1), (50, 400), (300, 5000)] {
            let mut pairs = Vec::with_capacity(m);
            for _ in 0..m {
                // Deliberately includes self-loops and duplicates.
                pairs.push((rng.below(n) as u32, rng.below(n) as u32));
            }
            let fast = GraphBuilder::new(n).edges(&pairs).build();
            let slow = GraphBuilder::new(n).edges(&pairs).build_reference();
            assert_eq!(fast.num_nodes(), slow.num_nodes());
            assert_eq!(fast.edges(), slow.edges());
            for v in 0..n as u32 {
                assert_eq!(fast.neighbors(v), slow.neighbors(v), "row {v}");
            }
            fast.check_invariants().unwrap();
        }
    }

    #[test]
    fn rows_sorted_on_large_random() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(5);
        let n = 500;
        let mut b = GraphBuilder::new(n);
        for _ in 0..5000 {
            let u = rng.below(n) as u32;
            let v = rng.below(n) as u32;
            if u != v {
                b.edge(u, v);
            }
        }
        let g = b.edges(&[]).build();
        g.check_invariants().unwrap();
    }
}
