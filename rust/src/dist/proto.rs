//! The coordinator ↔ worker wire protocol.
//!
//! A deliberately small, length-prefixed binary protocol over a byte
//! stream (TCP on `127.0.0.1` or a Unix-domain socket — [`Stream`]
//! abstracts the two). Every message is one *frame*:
//!
//! ```text
//! u8 tag | u64 payload_len (LE) | payload
//! ```
//!
//! and the per-epoch conversation is exactly the paper's communication
//! model: the coordinator broadcasts the parameter vector (+ the centrally
//! drawn DropEdge mask pick) to every worker, each worker runs its local
//! `train_step` with **zero** embedding exchange, and sends back the
//! per-partition `TrainOut` partial sum. Nothing else ever crosses a
//! process boundary, so bytes-on-wire per epoch is `p × (|θ| + |∇|)` plus
//! a few dozen bytes of framing — the quantity `bench_dist` reports as
//! `bytes_per_epoch_per_param`.
//!
//! Handshake sequence (worker-initiated):
//!
//! ```text
//! worker → Hello   { proto_version, rank, num_parts }
//! coord  → Config  { seed, dropedge, model }
//! worker → Meta    { local_train_weight, tmask_sum, num_masks }
//! repeat: coord → Step { pick, params }, worker → StepResult { TrainOut }
//! coord  → Shutdown
//! ```
//!
//! All payload scalars are little-endian via [`crate::util::binio`]; f32
//! tensors round-trip bit-exactly, which is what makes the cross-process
//! trajectory bit-identical to the in-process engine.

use crate::runtime::{ModelConfig, TrainOut};
use crate::util::binio;
use anyhow::{bail, ensure, Context, Result};
use std::io::{Read, Write};
use std::net::TcpStream;
#[cfg(unix)]
use std::os::unix::net::UnixStream;

/// Bump on any frame-layout change.
pub const PROTO_VERSION: u32 = 1;

/// Sanity cap on a single frame payload (1 GiB).
const MAX_FRAME: u64 = 1 << 30;

const TAG_HELLO: u8 = 1;
const TAG_CONFIG: u8 = 2;
const TAG_META: u8 = 3;
const TAG_STEP: u8 = 4;
const TAG_STEP_RESULT: u8 = 5;
const TAG_SHUTDOWN: u8 = 6;

/// A connected byte stream: TCP or Unix-domain socket.
pub enum Stream {
    Tcp(TcpStream),
    #[cfg(unix)]
    Unix(UnixStream),
}

impl Stream {
    /// Connect to `addr`: `unix:/path/to.sock` or `host:port`.
    pub fn connect(addr: &str) -> Result<Stream> {
        if let Some(path) = addr.strip_prefix("unix:") {
            #[cfg(unix)]
            {
                let s = UnixStream::connect(path)
                    .with_context(|| format!("connect unix socket {path}"))?;
                return Ok(Stream::Unix(s));
            }
            #[cfg(not(unix))]
            bail!("unix-socket transport is not available on this platform ({path})");
        }
        let s = TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
        // Frames are small and latency-bound; never wait on Nagle.
        s.set_nodelay(true)?;
        Ok(Stream::Tcp(s))
    }

    pub fn from_tcp(s: TcpStream) -> Result<Stream> {
        s.set_nodelay(true)?;
        Ok(Stream::Tcp(s))
    }

    #[cfg(unix)]
    pub fn from_unix(s: UnixStream) -> Stream {
        Stream::Unix(s)
    }

    /// Bound blocking reads (used by the coordinator during the handshake
    /// so a peer that connects but never speaks cannot hang it; `None`
    /// restores unbounded reads for the step loop).
    pub fn set_read_timeout(&self, dur: Option<std::time::Duration>) -> std::io::Result<()> {
        match self {
            Stream::Tcp(s) => s.set_read_timeout(dur),
            #[cfg(unix)]
            Stream::Unix(s) => s.set_read_timeout(dur),
        }
    }
}

impl Read for Stream {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match self {
            Stream::Tcp(s) => s.read(buf),
            #[cfg(unix)]
            Stream::Unix(s) => s.read(buf),
        }
    }
}

impl Write for Stream {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match self {
            Stream::Tcp(s) => s.write(buf),
            #[cfg(unix)]
            Stream::Unix(s) => s.write(buf),
        }
    }
    fn flush(&mut self) -> std::io::Result<()> {
        match self {
            Stream::Tcp(s) => s.flush(),
            #[cfg(unix)]
            Stream::Unix(s) => s.flush(),
        }
    }
}

/// A decoded protocol message.
#[derive(Clone, Debug)]
pub enum Frame {
    Hello { proto_version: u32, rank: u32, num_parts: u32 },
    Config { seed: u64, dropedge_k: u32, dropedge_ratio: f64, model: ModelConfig },
    Meta { local_train_weight: f64, tmask_sum: f64, num_masks: u32 },
    Step { pick: Option<usize>, params: Vec<Vec<f32>> },
    StepResult { out: TrainOut, compute_seconds: f64 },
    Shutdown,
}

fn put_tensor_list(w: &mut impl Write, tensors: &[Vec<f32>]) -> Result<()> {
    binio::write_u32(w, tensors.len() as u32)?;
    for t in tensors {
        binio::write_f32s(w, t)?;
    }
    Ok(())
}

fn get_tensor_list(r: &mut impl Read) -> Result<Vec<Vec<f32>>> {
    let k = binio::read_u32(r)? as usize;
    ensure!(k <= 4096, "corrupt frame: {k} tensors");
    (0..k).map(|_| binio::read_f32s(r)).collect()
}

fn put_model(w: &mut impl Write, m: &ModelConfig) -> Result<()> {
    for d in [m.layers, m.feat_dim, m.hidden, m.classes] {
        binio::write_u32(w, d as u32)?;
    }
    Ok(())
}

fn get_model(r: &mut impl Read) -> Result<ModelConfig> {
    Ok(ModelConfig {
        layers: binio::read_u32(r)? as usize,
        feat_dim: binio::read_u32(r)? as usize,
        hidden: binio::read_u32(r)? as usize,
        classes: binio::read_u32(r)? as usize,
    })
}

/// Write one frame; returns total bytes on the wire (header + payload).
pub fn write_frame(w: &mut impl Write, frame: &Frame) -> Result<u64> {
    let mut payload = Vec::new();
    let tag = match frame {
        Frame::Hello { proto_version, rank, num_parts } => {
            binio::write_u32(&mut payload, *proto_version)?;
            binio::write_u32(&mut payload, *rank)?;
            binio::write_u32(&mut payload, *num_parts)?;
            TAG_HELLO
        }
        Frame::Config { seed, dropedge_k, dropedge_ratio, model } => {
            binio::write_u64(&mut payload, *seed)?;
            binio::write_u32(&mut payload, *dropedge_k)?;
            binio::write_f64(&mut payload, *dropedge_ratio)?;
            put_model(&mut payload, model)?;
            TAG_CONFIG
        }
        Frame::Meta { local_train_weight, tmask_sum, num_masks } => {
            binio::write_f64(&mut payload, *local_train_weight)?;
            binio::write_f64(&mut payload, *tmask_sum)?;
            binio::write_u32(&mut payload, *num_masks)?;
            TAG_META
        }
        Frame::Step { pick, params } => {
            let pick_code: i64 = match pick {
                None => -1,
                Some(k) => *k as i64,
            };
            binio::write_u64(&mut payload, pick_code as u64)?;
            put_tensor_list(&mut payload, params)?;
            TAG_STEP
        }
        Frame::StepResult { out, compute_seconds } => {
            binio::write_f32(&mut payload, out.loss_sum)?;
            binio::write_f32(&mut payload, out.weight_sum)?;
            binio::write_f32(&mut payload, out.correct)?;
            binio::write_f64(&mut payload, *compute_seconds)?;
            put_tensor_list(&mut payload, &out.grads)?;
            TAG_STEP_RESULT
        }
        Frame::Shutdown => TAG_SHUTDOWN,
    };
    write_raw(w, tag, &payload)
}

/// A parameter payload pre-encoded once per epoch. A `Step` frame is the
/// 8-byte pick code followed by this body; only the pick differs across
/// workers, so the coordinator serializes the tensors once and streams
/// the same bytes to every worker ([`write_step_encoded`]).
pub struct EncodedParams {
    body: Vec<u8>,
}

impl EncodedParams {
    pub fn encode(params: &[Vec<f32>]) -> Result<EncodedParams> {
        let mut body = Vec::new();
        put_tensor_list(&mut body, params)?;
        Ok(EncodedParams { body })
    }
}

/// Broadcast-side fast path: write a `Step` frame from a pre-encoded
/// parameter payload (no per-worker re-serialization).
pub fn write_step_encoded(
    w: &mut impl Write,
    pick: Option<usize>,
    params: &EncodedParams,
) -> Result<u64> {
    let pick_code: i64 = match pick {
        None => -1,
        Some(k) => k as i64,
    };
    let mut header = [0u8; 17];
    header[0] = TAG_STEP;
    let len = 8 + params.body.len() as u64;
    header[1..9].copy_from_slice(&len.to_le_bytes());
    header[9..17].copy_from_slice(&(pick_code as u64).to_le_bytes());
    w.write_all(&header)?;
    w.write_all(&params.body)?;
    w.flush()?;
    Ok(9 + len)
}

/// One-off `Step` write (tests; single-worker sends). Byte-identical to
/// [`write_step_encoded`] with a fresh [`EncodedParams`].
pub fn write_step(w: &mut impl Write, pick: Option<usize>, params: &[Vec<f32>]) -> Result<u64> {
    write_step_encoded(w, pick, &EncodedParams::encode(params)?)
}

fn write_raw(w: &mut impl Write, tag: u8, payload: &[u8]) -> Result<u64> {
    let mut header = [0u8; 9];
    header[0] = tag;
    header[1..9].copy_from_slice(&(payload.len() as u64).to_le_bytes());
    w.write_all(&header)?;
    w.write_all(payload)?;
    w.flush()?;
    Ok(9 + payload.len() as u64)
}

/// Read one frame; returns the decoded message and its wire size.
pub fn read_frame(r: &mut impl Read) -> Result<(Frame, u64)> {
    let mut header = [0u8; 9];
    r.read_exact(&mut header).context("reading frame header (peer closed?)")?;
    let tag = header[0];
    let len = u64::from_le_bytes(header[1..9].try_into().unwrap());
    ensure!(len <= MAX_FRAME, "frame payload {len} exceeds sanity cap {MAX_FRAME}");
    let mut payload = vec![0u8; len as usize];
    r.read_exact(&mut payload).context("reading frame payload")?;
    let mut p: &[u8] = &payload;
    let frame = match tag {
        TAG_HELLO => Frame::Hello {
            proto_version: binio::read_u32(&mut p)?,
            rank: binio::read_u32(&mut p)?,
            num_parts: binio::read_u32(&mut p)?,
        },
        TAG_CONFIG => Frame::Config {
            seed: binio::read_u64(&mut p)?,
            dropedge_k: binio::read_u32(&mut p)?,
            dropedge_ratio: binio::read_f64(&mut p)?,
            model: get_model(&mut p)?,
        },
        TAG_META => Frame::Meta {
            local_train_weight: binio::read_f64(&mut p)?,
            tmask_sum: binio::read_f64(&mut p)?,
            num_masks: binio::read_u32(&mut p)?,
        },
        TAG_STEP => {
            let pick_code = binio::read_u64(&mut p)? as i64;
            let params = get_tensor_list(&mut p)?;
            ensure!(pick_code >= -1, "corrupt Step frame: pick {pick_code}");
            let pick = if pick_code < 0 { None } else { Some(pick_code as usize) };
            Frame::Step { pick, params }
        }
        TAG_STEP_RESULT => {
            let loss_sum = binio::read_f32(&mut p)?;
            let weight_sum = binio::read_f32(&mut p)?;
            let correct = binio::read_f32(&mut p)?;
            let compute_seconds = binio::read_f64(&mut p)?;
            let grads = get_tensor_list(&mut p)?;
            Frame::StepResult {
                out: TrainOut { loss_sum, weight_sum, correct, grads },
                compute_seconds,
            }
        }
        TAG_SHUTDOWN => Frame::Shutdown,
        other => bail!("unknown frame tag {other}"),
    };
    ensure!(p.is_empty(), "frame tag {tag}: {} trailing payload bytes", p.len());
    Ok((frame, 9 + len))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(f: &Frame) -> Frame {
        let mut buf = Vec::new();
        let n = write_frame(&mut buf, f).unwrap();
        assert_eq!(n as usize, buf.len());
        let mut r: &[u8] = &buf;
        let (got, m) = read_frame(&mut r).unwrap();
        assert_eq!(m as usize, buf.len());
        assert!(r.is_empty());
        got
    }

    #[test]
    fn hello_config_meta_roundtrip() {
        let model = ModelConfig { layers: 2, feat_dim: 8, hidden: 16, classes: 4 };
        match roundtrip(&Frame::Hello { proto_version: 1, rank: 3, num_parts: 8 }) {
            Frame::Hello { proto_version, rank, num_parts } => {
                assert_eq!((proto_version, rank, num_parts), (1, 3, 8));
            }
            other => panic!("{other:?}"),
        }
        match roundtrip(&Frame::Config {
            seed: 42,
            dropedge_k: 5,
            dropedge_ratio: 0.25,
            model,
        }) {
            Frame::Config { seed, dropedge_k, dropedge_ratio, model: m } => {
                assert_eq!((seed, dropedge_k, dropedge_ratio), (42, 5, 0.25));
                assert_eq!(m, model);
            }
            other => panic!("{other:?}"),
        }
        match roundtrip(&Frame::Meta {
            local_train_weight: 12.5,
            tmask_sum: 30.0,
            num_masks: 4,
        }) {
            Frame::Meta { local_train_weight, tmask_sum, num_masks } => {
                assert_eq!((local_train_weight, tmask_sum, num_masks), (12.5, 30.0, 4));
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn step_roundtrip_and_fast_path_agree() {
        let params = vec![vec![1.0f32, -2.5, 3.25], vec![0.0, f32::MIN_POSITIVE]];
        let mut a = Vec::new();
        write_frame(&mut a, &Frame::Step { pick: Some(2), params: params.clone() }).unwrap();
        let mut b = Vec::new();
        write_step(&mut b, Some(2), &params).unwrap();
        assert_eq!(a, b, "fast path must emit identical bytes");
        let mut r: &[u8] = &a;
        match read_frame(&mut r).unwrap().0 {
            Frame::Step { pick, params: p } => {
                assert_eq!(pick, Some(2));
                assert_eq!(p, params);
            }
            other => panic!("{other:?}"),
        }
        // pick = None encodes as -1.
        let mut c = Vec::new();
        write_step(&mut c, None, &params).unwrap();
        let mut r: &[u8] = &c;
        match read_frame(&mut r).unwrap().0 {
            Frame::Step { pick, .. } => assert_eq!(pick, None),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn step_result_roundtrip_bit_exact() {
        let out = TrainOut {
            loss_sum: 3.75,
            weight_sum: 11.0,
            correct: 7.0,
            grads: vec![vec![0.1f32, -0.0, f32::NAN], vec![1e-30]],
        };
        match roundtrip(&Frame::StepResult { out: out.clone(), compute_seconds: 0.125 }) {
            Frame::StepResult { out: got, compute_seconds } => {
                assert_eq!(compute_seconds, 0.125);
                assert_eq!(got.loss_sum, out.loss_sum);
                assert_eq!(got.weight_sum, out.weight_sum);
                assert_eq!(got.correct, out.correct);
                assert_eq!(got.grads.len(), out.grads.len());
                for (a, b) in got.grads.iter().zip(&out.grads) {
                    let ab: Vec<u32> = a.iter().map(|x| x.to_bits()).collect();
                    let bb: Vec<u32> = b.iter().map(|x| x.to_bits()).collect();
                    assert_eq!(ab, bb);
                }
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn shutdown_and_garbage() {
        assert!(matches!(roundtrip(&Frame::Shutdown), Frame::Shutdown));
        let mut r: &[u8] = &[99u8, 0, 0, 0, 0, 0, 0, 0, 0];
        assert!(read_frame(&mut r).is_err(), "unknown tag must error");
        let mut r2: &[u8] = &[1u8, 2, 0];
        assert!(read_frame(&mut r2).is_err(), "truncated header must error");
    }
}
