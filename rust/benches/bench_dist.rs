//! Distributed-runtime benchmark: shard store I/O throughput and the
//! multi-process coordinator/worker protocol vs the in-process engine.
//!
//! Run: `cargo bench --bench bench_dist`. Knobs (environment):
//! * `COFREE_BENCH_DIST_EDGES`  — target raw edge count (default 200_000)
//! * `COFREE_BENCH_DIST_EPOCHS` — training epochs per timing run (default 3)
//! * `COFREE_BENCH_DIST_PARTS`  — comma list of worker counts (default `2,4,8`)
//! * `COFREE_BENCH_DIST_OUT`    — output JSON path (default `BENCH_dist.json`)
//!
//! For each p the bench: (1) writes and re-loads the shard store, timing
//! both sides (MB/s); (2) trains the same cut for E epochs in-process and
//! across p real worker processes, reporting per-epoch wall clock, wire
//! bytes per epoch, and the headline `bytes_per_epoch_per_param` — which
//! is bounded by ≈ `8·p` (4 bytes of θ down + 4 of ∇ up per worker)
//! regardless of graph size, CoFree's whole point; and (3) asserts that
//! the two trajectories end in bit-identical parameters (`parity` in the
//! JSON must be true).

use cofree_gnn::dist::proto::WireCodec;
use cofree_gnn::dist::{self, MappedShard, ProcOptions, Shard, EXPECTED_F32_BYTES_PER_PARAM};
use cofree_gnn::graph::features::{synthesize, FeatureParams};
use cofree_gnn::graph::generators::{rmat_pairs, RmatParams};
use cofree_gnn::graph::{Dataset, GraphBuilder};
use cofree_gnn::partition::{algorithm, dar_weights, Reweighting, VertexCut};
use cofree_gnn::train::engine::{TrainConfig, TrainEngine};
use cofree_gnn::train::model::ModelKind;
use cofree_gnn::train::Precision;
use cofree_gnn::util::binio::Verify;
use cofree_gnn::util::rng::Rng;
use std::fmt::Write as _;
use std::path::PathBuf;
use std::time::Instant;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn env_string(key: &str, default: &str) -> String {
    std::env::var(key).unwrap_or_else(|_| default.to_string())
}

struct Row {
    p: usize,
    shard_bytes: u64,
    shard_write_s: f64,
    shard_load_s: f64,
    /// Worker-style mmap open with the whole-file digest verified (the
    /// default path) vs `--no-verify`: the integrity tax at load time.
    mmap_verified_s: f64,
    mmap_noverify_s: f64,
    inproc_epoch_s: f64,
    proc_epoch_s: f64,
    handshake_s: f64,
    wire_bytes_per_epoch: f64,
    bytes_per_epoch_per_param: f64,
    parity: bool,
}

fn main() {
    let target = env_usize("COFREE_BENCH_DIST_EDGES", 200_000);
    let epochs = env_usize("COFREE_BENCH_DIST_EPOCHS", 3);
    let parts_list = env_string("COFREE_BENCH_DIST_PARTS", "2,4,8");
    let out_path = env_string("COFREE_BENCH_DIST_OUT", "BENCH_dist.json");
    let parts: Vec<usize> = parts_list
        .split(',')
        .filter_map(|s| s.trim().parse().ok())
        .filter(|&p| p >= 1)
        .collect();
    let seed = 42u64;
    let worker_bin = PathBuf::from(env!("CARGO_BIN_EXE_cofree"));

    // R-MAT graph + synthetic supervision, one dataset for every p.
    let mut rng = Rng::new(0xD157);
    let scale = ((target / 10).max(2) as f64).log2().ceil() as u32;
    let n = 1usize << scale;
    let pairs = rmat_pairs(scale, target, RmatParams::default(), &mut rng);
    let g = GraphBuilder::new(n).edges(&pairs).build();
    let classes = 16usize;
    let comm: Vec<u32> = (0..n).map(|i| (i % classes) as u32).collect();
    let nd = synthesize(&comm, classes, &FeatureParams { dim: 64, ..Default::default() }, &mut rng.fork(3));
    let ds = Dataset { name: "rmat-dist-bench".into(), graph: g, data: nd, layers: 2, hidden: 64 };
    println!("== bench_dist: shard store + proc transport vs inproc ==");
    println!(
        "n={}, m={}, epochs={epochs}, parts={parts:?}, worker_bin={}",
        ds.graph.num_nodes(),
        ds.graph.num_edges(),
        worker_bin.display()
    );

    let mut rows: Vec<Row> = Vec::new();
    for &p in &parts {
        let vc = VertexCut::create(&ds.graph, p, algorithm("dbh").unwrap().as_ref(), &mut Rng::new(seed));
        let weights = dar_weights(&ds.graph, &vc, Reweighting::Dar);

        // Shard store: write throughput…
        let dir = std::env::temp_dir().join(format!("cofree_bench_dist_{}_{p}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let t0 = Instant::now();
        let stats = dist::write_shards(&ds, &vc, &weights, seed, &dir).expect("write shards");
        let shard_write_s = t0.elapsed().as_secs_f64();
        // …and load throughput (full streamed read of every shard).
        let files = dist::shard_files(&dir).expect("shard files");
        let t1 = Instant::now();
        let mut loaded_edges = 0usize;
        for f in &files {
            loaded_edges += Shard::read(f).expect("read shard").local.num_edges();
        }
        let shard_load_s = t1.elapsed().as_secs_f64();
        assert_eq!(loaded_edges, ds.graph.num_edges(), "shards lost edges");
        // The integrity tax: the worker's mmap open with the whole-file
        // digest checked (default) vs `--no-verify` (skip).
        let tv = Instant::now();
        for f in &files {
            MappedShard::open_with(f, Verify::Full).expect("verified mmap load");
        }
        let mmap_verified_s = tv.elapsed().as_secs_f64();
        let ts = Instant::now();
        for f in &files {
            MappedShard::open_with(f, Verify::Skip).expect("unverified mmap load");
        }
        let mmap_noverify_s = ts.elapsed().as_secs_f64();

        // In-process reference trajectory.
        let cfg = TrainConfig { epochs, eval_every: 0, seed, ..Default::default() };
        let mut engine = TrainEngine::native();
        let mut run = engine
            .prepare_partitions(&ds, &vc, Reweighting::Dar, None, seed)
            .expect("prepare inproc");
        let t2 = Instant::now();
        let (_, params_in, _) = engine.train(&mut run, None, &cfg).expect("inproc train");
        let inproc_epoch_s = t2.elapsed().as_secs_f64() / epochs as f64;

        // Multi-process trajectory over the same shards.
        let opts = ProcOptions::new(worker_bin.clone());
        let t3 = Instant::now();
        let (_, ck, dstats) =
            dist::train_over_shards(&ds, &dir, &cfg, &opts, None).expect("proc train");
        let proc_total_s = t3.elapsed().as_secs_f64();
        let proc_epoch_s = (proc_total_s - dstats.handshake_seconds).max(0.0) / epochs as f64;
        let parity = params_in.data == ck.params.data;
        let _ = std::fs::remove_dir_all(&dir);

        let row = Row {
            p,
            shard_bytes: stats.total_bytes,
            shard_write_s,
            shard_load_s,
            mmap_verified_s,
            mmap_noverify_s,
            inproc_epoch_s,
            proc_epoch_s,
            handshake_s: dstats.handshake_seconds,
            wire_bytes_per_epoch: dstats.bytes_per_epoch(),
            bytes_per_epoch_per_param: dstats.bytes_per_epoch_per_param(),
            parity,
        };
        let mib = row.shard_bytes as f64 / (1024.0 * 1024.0);
        let verify_overhead_pct = (row.mmap_verified_s - row.mmap_noverify_s).max(0.0)
            / row.mmap_noverify_s.max(1e-9)
            * 100.0;
        println!(
            "p={p:<3} shards {mib:7.1} MiB (write {:6.1} MiB/s, load {:6.1} MiB/s, mmap verify {:6.4}s vs skip {:6.4}s = +{verify_overhead_pct:.0}%)  epoch inproc {:7.4}s proc {:7.4}s  wire {:8.1} KiB/epoch ({:.2} B/epoch/param)  parity={}",
            mib / row.shard_write_s.max(1e-9),
            mib / row.shard_load_s.max(1e-9),
            row.mmap_verified_s,
            row.mmap_noverify_s,
            row.inproc_epoch_s,
            row.proc_epoch_s,
            row.wire_bytes_per_epoch / 1024.0,
            row.bytes_per_epoch_per_param,
            row.parity
        );
        assert!(row.parity, "p={p}: multi-process trajectory diverged from inproc");
        // The communication-free bound, now a named constant shared with
        // the compressed-path expectations below: uncompressed traffic is
        // EXPECTED_F32_BYTES_PER_PARAM·p per parameter per epoch plus
        // small framing overhead.
        let ideal = (EXPECTED_F32_BYTES_PER_PARAM * p) as f64;
        assert!(
            row.bytes_per_epoch_per_param >= ideal
                && row.bytes_per_epoch_per_param < ideal * 1.25,
            "p={p}: wire bytes/param/epoch {} outside [{ideal}, {ideal}·1.25)",
            row.bytes_per_epoch_per_param
        );
        rows.push(row);
    }

    // Fault-tolerance cost: kill one worker mid-run (chaos shim) with
    // heartbeats on every epoch, and measure what recovery and liveness
    // actually cost — wall-clock inside recovery, ping traffic per epoch —
    // while still requiring bit-identical parameters at the end.
    let recovery = {
        let p = *parts.first().unwrap_or(&2);
        let vc = VertexCut::create(&ds.graph, p, algorithm("dbh").unwrap().as_ref(), &mut Rng::new(seed));
        let weights = dar_weights(&ds.graph, &vc, Reweighting::Dar);
        let dir = std::env::temp_dir()
            .join(format!("cofree_bench_dist_rec_{}_{p}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dist::write_shards(&ds, &vc, &weights, seed, &dir).expect("write shards");
        let cfg = TrainConfig { epochs, eval_every: 0, seed, ..Default::default() };
        let mut engine = TrainEngine::native();
        let mut run = engine
            .prepare_partitions(&ds, &vc, Reweighting::Dar, None, seed)
            .expect("prepare inproc");
        let (_, params_in, _) = engine.train(&mut run, None, &cfg).expect("inproc train");
        let kill_step = 2.min(epochs.max(1));
        let opts = ProcOptions {
            chaos_env: Some(format!("kill:rank=0:step={kill_step}:once")),
            health: dist::HealthOptions { heartbeat_every: 1, ..Default::default() },
            ..ProcOptions::new(worker_bin.clone())
        };
        let (_, ck, dstats) =
            dist::train_over_shards(&ds, &dir, &cfg, &opts, None).expect("chaos train");
        let _ = std::fs::remove_dir_all(&dir);
        let parity = params_in.data == ck.params.data;
        println!(
            "recovery p={p}: {} recoveries in {:.4}s, heartbeats {:.1} B/epoch, parity={parity}",
            dstats.recoveries,
            dstats.recovery_seconds,
            dstats.heartbeat_bytes_per_epoch()
        );
        assert!(parity, "recovered trajectory diverged from inproc");
        assert!(dstats.recoveries >= 1, "kill fault never triggered a recovery");
        (p, dstats, parity)
    };

    // Precision tiers over the real wire: the same fleet at the first p,
    // once with bf16 storage + the bf16 codec (bit-identical to the
    // in-process bf16 trajectory — the wire-parity invariant) and once
    // with the int8 codec on the f32 tier (lossy, ratio-gated). The f32
    // row above is the epoch-time baseline; the accuracy check runs the
    // in-process engine at both tiers over the same cut.
    let precision_json = {
        let p = *parts.first().unwrap_or(&2);
        let vc = VertexCut::create(&ds.graph, p, algorithm("dbh").unwrap().as_ref(), &mut Rng::new(seed));
        let weights = dar_weights(&ds.graph, &vc, Reweighting::Dar);
        let dir = std::env::temp_dir()
            .join(format!("cofree_bench_dist_prec_{}_{p}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dist::write_shards(&ds, &vc, &weights, seed, &dir).expect("write shards");
        let cfg = TrainConfig { epochs, eval_every: 0, seed, ..Default::default() };

        // In-process bf16 reference trajectory (and the f32/bf16 accuracy
        // delta through the real evaluator, in percentage points).
        let mut acc_pair = [f64::NAN; 2];
        let mut params_bf16_in = None;
        for (slot, prec) in acc_pair.iter_mut().zip([Precision::F32, Precision::Bf16]) {
            let mut engine = TrainEngine::native_model_prec(ModelKind::Sage, prec);
            let mut run = engine
                .prepare_partitions(&ds, &vc, Reweighting::Dar, None, seed)
                .expect("prepare precision run");
            let eval = engine.prepare_eval(&ds).expect("prepare eval");
            let (history, params, _) =
                engine.train(&mut run, Some(&eval), &cfg).expect("precision train");
            *slot = history.best().0;
            if prec == Precision::Bf16 {
                params_bf16_in = Some(params);
            }
        }
        let final_acc_delta = (acc_pair[1] - acc_pair[0]) * 100.0;

        // bf16 fleet: bf16 workers, bf16 wire codec.
        let bf16_opts = ProcOptions {
            precision: Precision::Bf16,
            wire_codec: WireCodec::Bf16,
            ..ProcOptions::new(worker_bin.clone())
        };
        let t = Instant::now();
        let (_, ck_h, dstats_h) =
            dist::train_over_shards(&ds, &dir, &cfg, &bf16_opts, None).expect("bf16 proc train");
        let bf16_total_s = t.elapsed().as_secs_f64();
        let bf16_epoch_s = (bf16_total_s - dstats_h.handshake_seconds).max(0.0) / epochs as f64;
        let bf16_parity = params_bf16_in.as_ref().map(|ps| ps.data == ck_h.params.data);
        assert_eq!(
            bf16_parity,
            Some(true),
            "bf16 fleet trajectory diverged from the in-process bf16 trajectory"
        );
        let bf16_ratio = dstats_h.compression_ratio();

        // int8 codec on the default f32 tier (lossy wire; no bitwise claim).
        let i8_opts =
            ProcOptions { wire_codec: WireCodec::I8, ..ProcOptions::new(worker_bin.clone()) };
        let (_, _, dstats_q) =
            dist::train_over_shards(&ds, &dir, &cfg, &i8_opts, None).expect("int8 proc train");
        let i8_ratio = dstats_q.compression_ratio();
        let _ = std::fs::remove_dir_all(&dir);

        let f32_epoch_s = rows.first().map(|r| r.proc_epoch_s).unwrap_or(f64::NAN);
        let epoch_speedup = f32_epoch_s / bf16_epoch_s.max(1e-12);
        assert!(bf16_ratio >= 1.9, "bf16 wire reduction {bf16_ratio:.3} below the 1.9x gate");
        assert!(i8_ratio >= 3.5, "int8 wire reduction {i8_ratio:.3} below the 3.5x gate");
        assert!(
            final_acc_delta.abs() <= 0.5,
            "bf16 accuracy delta {final_acc_delta:+.3} pt outside the 0.5 pt envelope"
        );
        println!(
            "precision p={p}: epoch f32 {f32_epoch_s:.4}s bf16 {bf16_epoch_s:.4}s ({epoch_speedup:.2}x)  wire bf16 {bf16_ratio:.2}x int8 {i8_ratio:.2}x  acc delta {final_acc_delta:+.2} pt  bf16-fleet parity=true"
        );
        format!(
            "{{\"workers\": {p}, \"epoch_speedup\": {epoch_speedup:.3}, \"epoch_f32_s\": {f32_epoch_s:.6}, \"epoch_bf16_s\": {bf16_epoch_s:.6}, \"wire_bytes_reduction\": {bf16_ratio:.3}, \"wire_bytes_reduction_int8\": {i8_ratio:.3}, \"final_acc_delta\": {final_acc_delta:.4}, \"parity\": true}}"
        )
    };

    // Headline: the middle worker count (p=4 with defaults).
    let headline = rows.get(rows.len() / 2).or_else(|| rows.last()).expect("no rows");
    let mut rows_json = String::new();
    for (i, r) in rows.iter().enumerate() {
        if i > 0 {
            rows_json.push_str(",\n    ");
        }
        write!(
            rows_json,
            "{{\"workers\": {}, \"shard\": {{\"bytes\": {}, \"write_s\": {:.6}, \"load_s\": {:.6}, \"write_mib_s\": {:.3}, \"load_mib_s\": {:.3}, \"mmap_verified_s\": {:.6}, \"mmap_noverify_s\": {:.6}, \"verify_overhead_pct\": {:.1}}}, \"epoch\": {{\"inproc_s\": {:.6}, \"proc_s\": {:.6}, \"handshake_s\": {:.6}}}, \"wire\": {{\"bytes_per_epoch\": {:.1}, \"bytes_per_epoch_per_param\": {:.3}}}, \"parity\": {}}}",
            r.p,
            r.shard_bytes,
            r.shard_write_s,
            r.shard_load_s,
            r.shard_bytes as f64 / (1024.0 * 1024.0) / r.shard_write_s.max(1e-9),
            r.shard_bytes as f64 / (1024.0 * 1024.0) / r.shard_load_s.max(1e-9),
            r.mmap_verified_s,
            r.mmap_noverify_s,
            (r.mmap_verified_s - r.mmap_noverify_s).max(0.0) / r.mmap_noverify_s.max(1e-9) * 100.0,
            r.inproc_epoch_s,
            r.proc_epoch_s,
            r.handshake_s,
            r.wire_bytes_per_epoch,
            r.bytes_per_epoch_per_param,
            r.parity
        )
        .unwrap();
    }
    let (rec_p, rec_stats, rec_parity) = recovery;
    let recovery_json = format!(
        "{{\"workers\": {rec_p}, \"recoveries\": {}, \"recovery_seconds\": {:.6}, \"deadline_misses\": {}, \"heartbeat_bytes_per_epoch\": {:.1}, \"parity\": {rec_parity}}}",
        rec_stats.recoveries,
        rec_stats.recovery_seconds,
        rec_stats.deadline_misses,
        rec_stats.heartbeat_bytes_per_epoch()
    );
    let json = format!(
        "{{\n  \"bench\": \"dist\",\n  \"config\": {{\"edges_target\": {target}, \"epochs\": {epochs}, \"seed\": {seed}}},\n  \"graph\": {{\"nodes\": {}, \"edges\": {}}},\n  \"machine\": {{\"logical_cpus\": {}}},\n  \"headline\": {{\"workers\": {}, \"bytes_per_epoch_per_param\": {:.3}, \"parity\": {}}},\n  \"recovery\": {recovery_json},\n  \"precision\": {precision_json},\n  \"rows\": [\n    {rows_json}\n  ]\n}}\n",
        ds.graph.num_nodes(),
        ds.graph.num_edges(),
        std::thread::available_parallelism().map(|x| x.get()).unwrap_or(1),
        headline.p,
        headline.bytes_per_epoch_per_param,
        headline.parity
    );
    std::fs::write(&out_path, &json).expect("writing bench JSON");
    println!("\nwrote {out_path}");
}
