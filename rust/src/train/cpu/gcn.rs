//! Native GCN (Kipf & Welling, 2017) forward + backward over a tensorized
//! batch.
//!
//! The layer recipe (see `train::model`):
//!
//! ```text
//! ĉ_v    = 1 + Σ_{e→v} w_e                      (self-loop-augmented in-weight)
//! agg_d  = Σ_{e→d} w_e / √(ĉ_s ĉ_d) · h_s      (symmetric normalization)
//! comb   = agg + h / ĉ                          (the Ã = A + I self term)
//! h'     = comb · W + b                         (ReLU on all but the last layer)
//! ```
//!
//! This is the paper's propagation rule `D̃^-1/2 Ã D̃^-1/2 H W` with the
//! batch's (possibly DropEdge-masked, DAR-carrying) edge weights standing
//! in for the adjacency entries. The GEMMs run through the packed kernels
//! in [`super::gemm`]; the aggregation walks the same [`EdgeCsr`] index as
//! the other models (per-destination rows, ascending edge-id accumulation
//! — deterministic for any rayon pool size); every temporary lives in the
//! caller-owned [`ModelWorkspace`], so the `*_into` entry points allocate
//! nothing. Backward treats the ĉ denominators as weight-only constants,
//! the same convention as Sage's mean denominators. The naive oracle is
//! `reference::forward` (`ModelKind::Gcn` arm); gradients are checked
//! against central finite differences below.

use super::gemm;
use super::sage::EdgeCsr;
use crate::runtime::{ModelConfig, ParamSet};
use crate::train::model::ModelKind;
use crate::train::workspace::ModelWorkspace;
use rayon::prelude::*;

/// Self-loop-augmented in-weight `ĉ_v = 1 + Σ_{e→v} w_e` per node
/// (ascending edge-id accumulation; always ≥ 1, so no epsilon clamp).
pub(crate) fn compute_denoms_hat(csr: &EdgeCsr, emask: &[f32], denom: &mut [f32]) {
    denom.par_iter_mut().enumerate().for_each(|(d, den)| {
        let lo = csr.in_off[d] as usize;
        let hi = csr.in_off[d + 1] as usize;
        let mut cnt = 1f32;
        for idx in lo..hi {
            let w = emask[csr.in_eid[idx] as usize];
            if w == 0.0 {
                continue;
            }
            cnt += w;
        }
        *den = cnt;
    });
}

/// Symmetric-normalized aggregation
/// `out[d] = Σ_{e→d} w_e / √(ĉ_s ĉ_d) · h[s]` into a caller-owned buffer.
pub(crate) fn aggregate_sym_into(
    csr: &EdgeCsr,
    emask: &[f32],
    h: &[f32],
    denom: &[f32],
    out: &mut [f32],
    d_in: usize,
) {
    out.par_chunks_mut(d_in).enumerate().for_each(|(d, row)| {
        row.fill(0.0);
        let cd = denom[d];
        let lo = csr.in_off[d] as usize;
        let hi = csr.in_off[d + 1] as usize;
        for idx in lo..hi {
            let w = emask[csr.in_eid[idx] as usize];
            if w == 0.0 {
                continue;
            }
            let s = csr.in_src[idx] as usize;
            let f = w / (denom[s] * cd).sqrt();
            let srow = &h[s * d_in..s * d_in + d_in];
            for (av, &hv) in row.iter_mut().zip(srow.iter()) {
                *av += f * hv;
            }
        }
    });
}

/// Backward of [`aggregate_sym_into`] w.r.t. `h`:
/// `out[s] = Σ_{e: src_e = s} w_e / √(ĉ_s ĉ_d) · dcomb[d]` (denominators
/// constant), same ascending-edge-id per-element order.
pub(crate) fn scatter_sym_into(
    csr: &EdgeCsr,
    emask: &[f32],
    denom: &[f32],
    dcomb: &[f32],
    out: &mut [f32],
    d_in: usize,
) {
    out.par_chunks_mut(d_in).enumerate().for_each(|(s, row)| {
        row.fill(0.0);
        let cs = denom[s];
        let lo = csr.out_off[s] as usize;
        let hi = csr.out_off[s + 1] as usize;
        for idx in lo..hi {
            let w = emask[csr.out_eid[idx] as usize];
            if w == 0.0 {
                continue;
            }
            let d = csr.out_dst[idx] as usize;
            let f = w / (cs * denom[d]).sqrt();
            let drow = &dcomb[d * d_in..d * d_in + d_in];
            for (dv, &gv) in row.iter_mut().zip(drow.iter()) {
                *dv += f * gv;
            }
        }
    });
}

/// Fast GCN forward pass into a caller-owned workspace; keeps every
/// intermediate needed by [`backward_into`]. Allocates nothing.
pub fn forward_into(
    cfg: &ModelConfig,
    params: &ParamSet,
    feat: &[f32],
    emask: &[f32],
    csr: &EdgeCsr,
    n: usize,
    ws: &mut ModelWorkspace,
) {
    debug_assert_eq!(cfg.kind, ModelKind::Gcn);
    debug_assert_eq!(feat.len(), n * cfg.feat_dim);
    debug_assert_eq!(csr.n, n);
    debug_assert_eq!(ws.n, n);
    let ModelWorkspace { outs, combs, denoms, .. } = ws;
    // ĉ depends only on the edge weights, not the layer or the
    // activations: one O(E) pass fills the single denominator buffer every
    // layer (and the backward) reads.
    compute_denoms_hat(csr, emask, &mut denoms[0]);
    for l in 0..cfg.layers {
        let d_in = if l == 0 { cfg.feat_dim } else { cfg.hidden };
        let d_out = if l == cfg.layers - 1 { cfg.classes } else { cfg.hidden };
        let w = &params.data[2 * l];
        let b = &params.data[2 * l + 1];
        let (prev, rest) = outs.split_at_mut(l);
        let hin: &[f32] = if l == 0 { feat } else { &prev[l - 1] };
        let comb = &mut combs[l];
        aggregate_sym_into(csr, emask, hin, &denoms[0], comb, d_in);
        // comb += h / ĉ (the normalized self-loop term).
        {
            let denom = &denoms[0];
            comb.par_chunks_mut(d_in).enumerate().for_each(|(i, row)| {
                let inv = 1.0 / denom[i];
                let srow = &hin[i * d_in..i * d_in + d_in];
                for (cv, &hv) in row.iter_mut().zip(srow.iter()) {
                    *cv += inv * hv;
                }
            });
        }
        let out = &mut rest[0];
        debug_assert_eq!(out.len(), n * d_out);
        gemm::broadcast_rows(b, out, d_out);
        gemm::matmul_acc(comb, w, out, n, d_in, d_out);
        if l != cfg.layers - 1 {
            out.par_iter_mut().for_each(|v| {
                if *v < 0.0 {
                    *v = 0.0;
                }
            });
        }
    }
}

/// Backward pass into caller-owned gradient tensors (`W, b` per layer).
/// Expects the logits gradient at the front of `ws.dbuf_a` (as left by
/// `loss_grad_into`). Every element of `grads` is overwritten; nothing
/// allocates.
#[allow(clippy::too_many_arguments)]
pub fn backward_into(
    cfg: &ModelConfig,
    params: &ParamSet,
    feat: &[f32],
    emask: &[f32],
    csr: &EdgeCsr,
    n: usize,
    ws: &mut ModelWorkspace,
    grads: &mut [Vec<f32>],
) {
    debug_assert_eq!(cfg.kind, ModelKind::Gcn);
    debug_assert_eq!(grads.len(), params.data.len());
    let _ = feat;
    let ModelWorkspace { outs, combs, denoms, dbuf_a, dbuf_b, dagg, dmsg, .. } = ws;
    for l in (0..cfg.layers).rev() {
        let d_in = if l == 0 { cfg.feat_dim } else { cfg.hidden };
        let d_out = if l == cfg.layers - 1 { cfg.classes } else { cfg.hidden };
        let w = &params.data[2 * l];
        let comb = &combs[l];
        let denom = &denoms[0];
        // Upstream gradient w.r.t. this layer's output; for hidden layers
        // push it through the ReLU (out = relu(pre), so mask by out > 0 —
        // out == 0 covers pre ≤ 0).
        if l != cfg.layers - 1 {
            dbuf_a[..n * d_out]
                .par_chunks_mut(d_out)
                .zip(outs[l].par_chunks(d_out))
                .for_each(|(drow, orow)| {
                    for (dv, &ov) in drow.iter_mut().zip(orow.iter()) {
                        if ov <= 0.0 {
                            *dv = 0.0;
                        }
                    }
                });
        }
        let dpre = &dbuf_a[..n * d_out];
        gemm::col_sums(dpre, n, d_out, &mut grads[2 * l + 1]);
        gemm::matmul_tn(comb, dpre, &mut grads[2 * l], n, d_in, d_out);
        // Input gradient for the next (shallower) layer — skipped at layer
        // 0, where the input is the feature data.
        if l == 0 {
            break;
        }
        let dcomb = &mut dagg[..n * d_in];
        gemm::matmul_nt(dpre, w, dcomb, n, d_out, d_in);
        let scat = &mut dmsg[..n * d_in];
        scatter_sym_into(csr, emask, denom, dcomb, scat, d_in);
        {
            let dcomb_ro: &[f32] = dcomb;
            let scat_ro: &[f32] = scat;
            let dh = &mut dbuf_b[..n * d_in];
            dh.par_chunks_mut(d_in).enumerate().for_each(|(i, row)| {
                let inv = 1.0 / denom[i];
                let crow = &dcomb_ro[i * d_in..i * d_in + d_in];
                let srow = &scat_ro[i * d_in..i * d_in + d_in];
                for ((dv, &cv), &sv) in row.iter_mut().zip(crow.iter()).zip(srow.iter()) {
                    *dv = inv * cv + sv;
                }
            });
        }
        std::mem::swap(dbuf_a, dbuf_b);
    }
}

#[cfg(test)]
mod tests {
    use super::super::sage::loss_grad_into;
    use super::*;
    use crate::graph::features::{synthesize, FeatureParams};
    use crate::partition::testutil::graph_zoo;
    use crate::partition::{dar_weights, random::RandomVertexCut, Reweighting, VertexCut};
    use crate::train::reference;
    use crate::train::tensorize::{tensorize_partition, TrainBatch};
    use crate::util::rng::Rng;

    fn assert_close(got: &[f32], want: &[f32], tol: f32, what: &str) {
        assert_eq!(got.len(), want.len(), "{what}: length");
        for (i, (&g, &w)) in got.iter().zip(want.iter()).enumerate() {
            assert!((g - w).abs() <= tol * (1.0 + w.abs()), "{what} elem {i}: got {g}, want {w}");
        }
    }

    fn zoo_batch(gi: usize, g: &crate::graph::Graph, seed: u64) -> Option<TrainBatch> {
        let n = g.num_nodes();
        let mut rng = Rng::new(seed + gi as u64);
        let comm: Vec<u32> = (0..n).map(|i| (i % 4) as u32).collect();
        let nd = synthesize(&comm, 4, &FeatureParams { dim: 5, ..Default::default() }, &mut rng);
        let vc = VertexCut::create(g, 2, &RandomVertexCut, &mut rng);
        let w = dar_weights(g, &vc, Reweighting::Dar);
        if vc.parts[0].num_edges() == 0 {
            return None;
        }
        Some(tensorize_partition(&vc.parts[0], &nd, &w[0], 256, 2048).unwrap())
    }

    /// The fast GCN forward matches the naive reference oracle across the
    /// graph zoo and layer counts, and is bit-identical for any rayon pool
    /// size.
    #[test]
    fn gcn_forward_matches_reference_across_zoo_and_threads() {
        for (gi, g) in graph_zoo(33).iter().enumerate() {
            let Some(batch) = zoo_batch(gi, g, 700) else { continue };
            let csr = EdgeCsr::from_batch(&batch);
            let emask = batch.emask().as_f32();
            let feat = batch.tensors[0].as_f32();
            let mut rng = Rng::new(900 + gi as u64);
            for layers in [1usize, 2, 3] {
                let cfg = ModelConfig {
                    kind: ModelKind::Gcn,
                    layers,
                    feat_dim: 5,
                    hidden: 7,
                    classes: 4,
                };
                let params = ParamSet::init_glorot(&cfg, &mut rng.fork(layers as u64));
                let want = reference::forward(&cfg, &params, &batch);
                let mut ws = ModelWorkspace::new(&cfg, batch.n_pad);
                forward_into(&cfg, &params, feat, emask, &csr, batch.n_pad, &mut ws);
                assert_close(ws.logits(), &want, 1e-4, "gcn logits");
                for threads in [1usize, 2, 8] {
                    let pool =
                        rayon::ThreadPoolBuilder::new().num_threads(threads).build().unwrap();
                    let mut ws_t = ModelWorkspace::new(&cfg, batch.n_pad);
                    pool.install(|| {
                        forward_into(&cfg, &params, feat, emask, &csr, batch.n_pad, &mut ws_t)
                    });
                    assert_eq!(
                        ws_t.logits(),
                        ws.logits(),
                        "graph#{gi} layers={layers}: gcn forward differs at {threads} threads"
                    );
                }
            }
        }
    }

    /// Central finite differences over every parameter tensor.
    #[test]
    fn gcn_backward_matches_finite_differences() {
        let mut rng = Rng::new(7);
        let g = crate::graph::generators::barabasi_albert(120, 3, &mut rng);
        let comm: Vec<u32> = (0..120).map(|i| (i % 3) as u32).collect();
        let nd = synthesize(&comm, 3, &FeatureParams { dim: 6, ..Default::default() }, &mut rng);
        let vc = VertexCut::create(&g, 2, &RandomVertexCut, &mut rng);
        let w = dar_weights(&g, &vc, Reweighting::Dar);
        let batch = tensorize_partition(&vc.parts[0], &nd, &w[0], 128, 1024).unwrap();
        let cfg =
            ModelConfig { kind: ModelKind::Gcn, layers: 2, feat_dim: 6, hidden: 8, classes: 3 };
        let mut params = ParamSet::init_glorot(&cfg, &mut rng);
        let csr = EdgeCsr::from_batch(&batch);
        let feat = batch.tensors[0].as_f32().to_vec();
        let emask = batch.emask().as_f32().to_vec();
        let dar = batch.tensors[4].as_f32().to_vec();
        let labels = batch.tensors[5].as_i32().to_vec();
        let tmask = batch.tensors[6].as_f32().to_vec();
        let n = batch.n_pad;
        let mut ws = ModelWorkspace::new(&cfg, n);
        let loss_of = |p: &ParamSet, ws: &mut ModelWorkspace| -> f64 {
            forward_into(&cfg, p, &feat, &emask, &csr, n, ws);
            loss_grad_into(&cfg, &dar, &labels, &tmask, n, ws).0
        };
        forward_into(&cfg, &params, &feat, &emask, &csr, n, &mut ws);
        let _ = loss_grad_into(&cfg, &dar, &labels, &tmask, n, &mut ws);
        let mut grads: Vec<Vec<f32>> =
            params.data.iter().map(|p| vec![0f32; p.len()]).collect();
        backward_into(&cfg, &params, &feat, &emask, &csr, n, &mut ws, &mut grads);
        let eps = 2e-2f32;
        let mut ws2 = ModelWorkspace::new(&cfg, n);
        let mut checked = 0usize;
        for pi in 0..params.data.len() {
            let len = params.data[pi].len();
            let step = (len / 25).max(1);
            for ei in (0..len).step_by(step) {
                let orig = params.data[pi][ei];
                params.data[pi][ei] = orig + eps;
                let lp = loss_of(&params, &mut ws2);
                params.data[pi][ei] = orig - eps;
                let lm = loss_of(&params, &mut ws2);
                params.data[pi][ei] = orig;
                let numeric = (lp - lm) / (2.0 * eps as f64);
                let analytic = grads[pi][ei] as f64;
                checked += 1;
                assert!(
                    (analytic - numeric).abs() <= 0.05 * numeric.abs().max(1.0) + 5e-3,
                    "param {pi} elem {ei}: analytic {analytic} vs numeric {numeric}"
                );
            }
        }
        assert!(checked > 20, "probe coverage too small: {checked}");
    }

    /// Isolated rows (no in-edges, ĉ = 1) reduce to `h·W + b`, and padding
    /// rows (zero features) to exactly `b`.
    #[test]
    fn gcn_isolated_and_padding_rows() {
        let mut rng = Rng::new(9);
        let g = crate::graph::generators::barabasi_albert(80, 2, &mut rng);
        let comm: Vec<u32> = (0..80).map(|i| (i % 3) as u32).collect();
        let nd = synthesize(&comm, 3, &FeatureParams { dim: 4, ..Default::default() }, &mut rng);
        let vc = VertexCut::create(&g, 2, &RandomVertexCut, &mut rng);
        let w = dar_weights(&g, &vc, Reweighting::Dar);
        let batch = tensorize_partition(&vc.parts[0], &nd, &w[0], 128, 1024).unwrap();
        let cfg =
            ModelConfig { kind: ModelKind::Gcn, layers: 1, feat_dim: 4, hidden: 8, classes: 3 };
        let params = ParamSet::init_glorot(&cfg, &mut rng);
        let csr = EdgeCsr::from_batch(&batch);
        let mut ws = ModelWorkspace::new(&cfg, batch.n_pad);
        forward_into(
            &cfg,
            &params,
            batch.tensors[0].as_f32(),
            batch.emask().as_f32(),
            &csr,
            batch.n_pad,
            &mut ws,
        );
        let b = &params.data[1];
        for i in batch.n_used..batch.n_pad {
            for j in 0..cfg.classes {
                assert!((ws.logits()[i * cfg.classes + j] - b[j]).abs() < 1e-6);
            }
        }
    }
}
