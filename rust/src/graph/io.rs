//! Graph (de)serialization.
//!
//! Three formats:
//! * **edge list text** — `u v` per line, `#` comments; interchange with
//!   external tools.
//! * **binary snapshot** — a compact little-endian dump of the CSR plus
//!   optional `NodeData`, so dataset generation cost is paid once per seed
//!   (`cofree gen --out g.bin`).
//! * **binary edge list** (`edges.bin`) — a flat CRC-trailed raw pair
//!   stream for out-of-core ingest: unlike the snapshot it carries *raw*
//!   pairs (duplicates, self-loops, either orientation) and is read in
//!   bounded-memory chunks ([`EdgeListBinReader`] is an
//!   [`EdgeSource`](crate::ingest::EdgeSource)), so `cofree shard --input
//!   edges.bin --stream` never materializes the edge list.

use super::builder::GraphBuilder;
use super::csr::Graph;
use super::features::NodeData;
use crate::ingest::EdgeSource;
use crate::util::binio;
use crate::util::hash::{HashingReader, HashingWriter};
use anyhow::{bail, ensure, Context, Result};
use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::path::{Path, PathBuf};

const MAGIC: &[u8; 8] = b"COFREEG1";

/// Magic of the binary raw edge-list format.
pub const EDGES_MAGIC: &[u8; 8] = b"COFREEL1";
/// Current binary edge-list format version.
pub const EDGES_VERSION: u32 = 1;

/// Write a graph as a text edge list.
pub fn write_edge_list(g: &Graph, path: &Path) -> Result<()> {
    let f = std::fs::File::create(path).with_context(|| format!("create {path:?}"))?;
    let mut w = BufWriter::new(f);
    writeln!(w, "# nodes {}", g.num_nodes())?;
    for &(u, v) in g.edges() {
        writeln!(w, "{u} {v}")?;
    }
    Ok(())
}

/// Read a text edge list (format written by [`write_edge_list`]; a
/// `# nodes N` header is honored, otherwise n = max id + 1).
pub fn read_edge_list(path: &Path) -> Result<Graph> {
    let f = std::fs::File::open(path).with_context(|| format!("open {path:?}"))?;
    let r = BufReader::new(f);
    let mut edges: Vec<(u32, u32)> = Vec::new();
    let mut n: Option<usize> = None;
    for (lineno, line) in r.lines().enumerate() {
        let line = line?;
        let t = line.trim();
        if t.is_empty() {
            continue;
        }
        if let Some(rest) = t.strip_prefix('#') {
            let mut it = rest.split_whitespace();
            if it.next() == Some("nodes") {
                if let Some(v) = it.next() {
                    n = Some(v.parse().context("bad # nodes header")?);
                }
            }
            continue;
        }
        let mut it = t.split_whitespace();
        let (u, v) = match (it.next(), it.next()) {
            (Some(a), Some(b)) => (a.parse::<u32>(), b.parse::<u32>()),
            _ => bail!("line {}: expected 'u v'", lineno + 1),
        };
        edges.push((u.context("bad u")?, v.context("bad v")?));
    }
    let n = n.unwrap_or_else(|| {
        edges.iter().map(|&(u, v)| u.max(v) as usize + 1).max().unwrap_or(0)
    });
    Ok(GraphBuilder::new(n).edges(&edges).build())
}

/// Write graph + optional node data as a binary snapshot.
pub fn write_snapshot(g: &Graph, nd: Option<&NodeData>, path: &Path) -> Result<()> {
    let f = std::fs::File::create(path).with_context(|| format!("create {path:?}"))?;
    let mut w = BufWriter::new(f);
    binio::write_magic(&mut w, MAGIC)?;
    binio::write_u64(&mut w, g.num_nodes() as u64)?;
    let flat: Vec<u32> = g.edges().iter().flat_map(|&(u, v)| [u, v]).collect();
    binio::write_u32s(&mut w, &flat)?;
    match nd {
        None => binio::write_u8(&mut w, 0)?,
        Some(nd) => {
            binio::write_u8(&mut w, 1)?;
            binio::write_u64(&mut w, nd.dim as u64)?;
            binio::write_u64(&mut w, nd.num_classes as u64)?;
            binio::write_f32s(&mut w, &nd.features)?;
            binio::write_u32s(&mut w, &nd.labels)?;
            binio::write_bytes(&mut w, &nd.split)?;
        }
    }
    Ok(())
}

/// Read a binary snapshot written by [`write_snapshot`].
///
/// A wrong or truncated header reports found-vs-expected bytes (the same
/// [`binio`] check the shard store and checkpoints use), so a truncated
/// snapshot is not misdiagnosed as "not a snapshot".
pub fn read_snapshot(path: &Path) -> Result<(Graph, Option<NodeData>)> {
    let f = std::fs::File::open(path).with_context(|| format!("open {path:?}"))?;
    let mut r = BufReader::new(f);
    binio::expect_magic(&mut r, MAGIC, "cofree graph snapshot")
        .with_context(|| format!("reading {path:?}"))?;
    let n = binio::read_u64(&mut r)? as usize;
    let flat = binio::read_u32s(&mut r).context("reading edge array")?;
    if flat.len() % 2 != 0 {
        bail!("corrupt edge array: odd endpoint count {}", flat.len());
    }
    let edges: Vec<(u32, u32)> = flat.chunks_exact(2).map(|c| (c[0], c[1])).collect();
    let g = GraphBuilder::new(n).edges(&edges).build();
    let nd = if binio::read_u8(&mut r)? == 1 {
        let dim = binio::read_u64(&mut r)? as usize;
        let num_classes = binio::read_u64(&mut r)? as usize;
        let features = binio::read_f32s(&mut r).context("reading features")?;
        let labels = binio::read_u32s(&mut r).context("reading labels")?;
        let split = binio::read_bytes(&mut r).context("reading split masks")?;
        Some(NodeData { features, dim, labels, num_classes, split })
    } else {
        None
    };
    Ok((g, nd))
}

// ---------------------------------------------------------------------------
// Binary raw edge list (out-of-core ingest input).
//
// Layout (little-endian): magic "COFREEL1" | u32 version | u64 num_nodes |
// u64 num_pairs | num_pairs × (u32 u, u32 v) | u32 CRC-32C trailer over
// every preceding byte.
// ---------------------------------------------------------------------------

/// Streaming writer for `edges.bin`: declares the pair count up front,
/// accumulates the CRC as pairs are appended, and commits through the
/// durable tmp → fsync → rename path.
pub struct EdgeListBinWriter {
    w: HashingWriter<BufWriter<std::fs::File>>,
    tmp: PathBuf,
    path: PathBuf,
    guard: Option<binio::TmpGuard>,
    num_nodes: u64,
    declared: u64,
    pushed: u64,
}

impl EdgeListBinWriter {
    /// Open `path` for writing a stream of exactly `num_pairs` raw pairs
    /// over `num_nodes` vertices.
    pub fn create(path: &Path, num_nodes: usize, num_pairs: u64) -> Result<EdgeListBinWriter> {
        let tmp = binio::tmp_sibling(path);
        let guard = binio::TmpGuard::new(tmp.clone());
        let f = std::fs::File::create(&tmp).with_context(|| format!("create {tmp:?}"))?;
        let mut w = HashingWriter::new(BufWriter::new(f));
        binio::write_magic(&mut w, EDGES_MAGIC)?;
        binio::write_version(&mut w, EDGES_VERSION)?;
        binio::write_u64(&mut w, num_nodes as u64)?;
        binio::write_u64(&mut w, num_pairs)?;
        Ok(EdgeListBinWriter {
            w,
            tmp,
            path: path.to_path_buf(),
            guard: Some(guard),
            num_nodes: num_nodes as u64,
            declared: num_pairs,
            pushed: 0,
        })
    }

    /// Append one raw pair (self-loops and duplicates are legal — this is
    /// the *raw* stream, canonicalization happens at ingest).
    #[inline]
    pub fn push(&mut self, u: u32, v: u32) -> Result<()> {
        ensure!(
            (u as u64) < self.num_nodes && (v as u64) < self.num_nodes,
            "pair ({u}, {v}) out of range for {} nodes",
            self.num_nodes
        );
        ensure!(self.pushed < self.declared, "more pairs than the declared {}", self.declared);
        binio::write_u32(&mut self.w, u)?;
        binio::write_u32(&mut self.w, v)?;
        self.pushed += 1;
        Ok(())
    }

    /// Verify the declared count was met, write the CRC trailer, and
    /// durably commit. Returns total bytes written.
    pub fn finish(mut self) -> Result<u64> {
        ensure!(
            self.pushed == self.declared,
            "declared {} pairs but only {} were pushed",
            self.declared,
            self.pushed
        );
        let digest = self.w.digest();
        binio::write_u32(&mut self.w, digest)?;
        let bytes = self.w.written();
        let mut bw = self.w.into_inner();
        bw.flush().with_context(|| format!("flushing {:?}", self.tmp))?;
        bw.get_ref().sync_all().with_context(|| format!("fsyncing {:?}", self.tmp))?;
        binio::commit_replace(&self.tmp, &self.path)?;
        self.guard.take().unwrap().disarm();
        Ok(bytes)
    }
}

/// Bounded-memory reader for `edges.bin`: pairs stream through a fixed
/// buffer, the running CRC is checked against the trailer at exhaustion,
/// and truncation vs. corruption produce distinct structured errors.
pub struct EdgeListBinReader {
    r: HashingReader<BufReader<std::fs::File>>,
    path: PathBuf,
    num_nodes: u64,
    num_pairs: u64,
    read: u64,
    verified: bool,
}

impl EdgeListBinReader {
    pub fn open(path: &Path) -> Result<EdgeListBinReader> {
        let f = std::fs::File::open(path).with_context(|| format!("open {path:?}"))?;
        let mut r = HashingReader::new(BufReader::new(f));
        binio::expect_magic(&mut r, EDGES_MAGIC, "cofree binary edge list")
            .with_context(|| format!("reading {path:?}"))?;
        binio::expect_version(&mut r, EDGES_VERSION, "binary edge list")?;
        let num_nodes = binio::read_u64(&mut r).context("reading node count")?;
        let num_pairs = binio::read_u64(&mut r).context("reading pair count")?;
        Ok(EdgeListBinReader {
            r,
            path: path.to_path_buf(),
            num_nodes,
            num_pairs,
            read: 0,
            verified: false,
        })
    }

    /// Declared raw pair count.
    pub fn num_pairs(&self) -> u64 {
        self.num_pairs
    }

    /// Next raw pair, or `None` at the (trailer-verified) end.
    fn next_pair(&mut self) -> Result<Option<(u32, u32)>> {
        if self.read == self.num_pairs {
            if !self.verified {
                self.verified = true;
                let want = self.r.digest();
                let got = binio::read_u32(&mut self.r).with_context(|| {
                    format!("truncated binary edge list {:?}: digest trailer missing", self.path)
                })?;
                ensure!(
                    got == want,
                    "binary edge list digest mismatch in {:?}: stored {got:#010x}, computed \
                     {want:#010x} — the file bytes are corrupt",
                    self.path
                );
                let mut probe = [0u8; 1];
                if self.r.read(&mut probe)? != 0 {
                    bail!("trailing bytes after binary edge list {:?}", self.path);
                }
            }
            return Ok(None);
        }
        let mut buf = [0u8; 8];
        self.r.read_exact(&mut buf).with_context(|| {
            format!(
                "truncated binary edge list {:?}: {} of {} pairs missing",
                self.path,
                self.num_pairs - self.read,
                self.num_pairs
            )
        })?;
        self.read += 1;
        Ok(Some((
            u32::from_le_bytes(buf[..4].try_into().unwrap()),
            u32::from_le_bytes(buf[4..].try_into().unwrap()),
        )))
    }
}

impl EdgeSource for EdgeListBinReader {
    fn num_nodes(&self) -> usize {
        self.num_nodes as usize
    }

    fn next_chunk(&mut self, cap: usize, buf: &mut Vec<(u32, u32)>) -> Result<usize> {
        let mut k = 0;
        while k < cap {
            match self.next_pair()? {
                Some(pair) => {
                    buf.push(pair);
                    k += 1;
                }
                None => break,
            }
        }
        Ok(k)
    }
}

/// Write a whole in-memory pair list as `edges.bin`.
pub fn write_edge_list_bin(num_nodes: usize, pairs: &[(u32, u32)], path: &Path) -> Result<u64> {
    let mut w = EdgeListBinWriter::create(path, num_nodes, pairs.len() as u64)?;
    for &(u, v) in pairs {
        w.push(u, v)?;
    }
    w.finish()
}

/// Read a whole `edges.bin` into memory (the non-streaming `cofree shard
/// --input` path), trailer-verified.
pub fn read_edge_list_bin(path: &Path) -> Result<(usize, Vec<(u32, u32)>)> {
    let mut r = EdgeListBinReader::open(path)?;
    let mut pairs = Vec::new();
    while let Some(pair) = r.next_pair()? {
        pairs.push(pair);
    }
    Ok((r.num_nodes as usize, pairs))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::features::{synthesize, FeatureParams};
    use crate::graph::generators::barabasi_albert;
    use crate::util::rng::Rng;

    fn tmp(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("cofree_io_test_{name}_{}", std::process::id()));
        p
    }

    #[test]
    fn edge_list_roundtrip() {
        let mut rng = Rng::new(20);
        let g = barabasi_albert(200, 2, &mut rng);
        let p = tmp("el");
        write_edge_list(&g, &p).unwrap();
        let g2 = read_edge_list(&p).unwrap();
        assert_eq!(g.num_nodes(), g2.num_nodes());
        assert_eq!(g.edges(), g2.edges());
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn snapshot_roundtrip_with_nodedata() {
        let mut rng = Rng::new(21);
        let g = barabasi_albert(150, 3, &mut rng);
        let comm: Vec<u32> = (0..150).map(|i| (i % 4) as u32).collect();
        let nd = synthesize(&comm, 4, &FeatureParams { dim: 8, ..Default::default() }, &mut rng);
        let p = tmp("snap");
        write_snapshot(&g, Some(&nd), &p).unwrap();
        let (g2, nd2) = read_snapshot(&p).unwrap();
        let nd2 = nd2.unwrap();
        assert_eq!(g.edges(), g2.edges());
        assert_eq!(nd.features, nd2.features);
        assert_eq!(nd.labels, nd2.labels);
        assert_eq!(nd.split, nd2.split);
        assert_eq!(nd.num_classes, nd2.num_classes);
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn snapshot_without_nodedata() {
        let mut rng = Rng::new(22);
        let g = barabasi_albert(50, 2, &mut rng);
        let p = tmp("snap2");
        write_snapshot(&g, None, &p).unwrap();
        let (g2, nd2) = read_snapshot(&p).unwrap();
        assert!(nd2.is_none());
        assert_eq!(g.edges(), g2.edges());
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn rejects_bad_magic_with_found_vs_expected() {
        let p = tmp("bad");
        std::fs::write(&p, b"NOTMAGIC........").unwrap();
        let err = read_snapshot(&p).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("COFREEG1"), "expected bytes missing: {msg}");
        assert!(msg.contains("NOTMAGIC"), "found bytes missing: {msg}");
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn truncated_snapshot_reports_truncation_not_bad_magic() {
        let p = tmp("trunc");
        std::fs::write(&p, b"COFRE").unwrap();
        let err = read_snapshot(&p).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("truncated"), "{msg}");
        std::fs::remove_file(&p).unwrap();
    }

    fn messy_pairs(n: usize, m: usize, seed: u64) -> Vec<(u32, u32)> {
        let mut rng = Rng::new(seed);
        (0..m).map(|_| (rng.below(n) as u32, rng.below(n) as u32)).collect()
    }

    #[test]
    fn edge_list_bin_roundtrip_preserves_raw_stream() {
        let pairs = messy_pairs(90, 500, 31);
        let p = tmp("elbin");
        write_edge_list_bin(90, &pairs, &p).unwrap();
        let (n, got) = read_edge_list_bin(&p).unwrap();
        assert_eq!(n, 90);
        assert_eq!(got, pairs, "raw order, duplicates and loops must survive");
        // And chunked through the EdgeSource interface, any chunk size.
        for cap in [1usize, 7, 4096] {
            let mut r = EdgeListBinReader::open(&p).unwrap();
            assert_eq!(r.num_pairs(), pairs.len() as u64);
            let mut streamed = Vec::new();
            loop {
                let mut buf = Vec::new();
                if r.next_chunk(cap, &mut buf).unwrap() == 0 {
                    break;
                }
                streamed.extend_from_slice(&buf);
            }
            assert_eq!(streamed, pairs, "cap={cap}");
        }
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn edge_list_bin_writer_enforces_declared_count() {
        let p = tmp("elbin_count");
        let mut w = EdgeListBinWriter::create(&p, 10, 2).unwrap();
        w.push(0, 1).unwrap();
        let err = w.finish().unwrap_err();
        assert!(format!("{err:#}").contains("declared 2 pairs"), "{err:#}");
        let mut w = EdgeListBinWriter::create(&p, 10, 1).unwrap();
        w.push(0, 1).unwrap();
        let err = w.push(1, 2).unwrap_err();
        assert!(format!("{err:#}").contains("more pairs"), "{err:#}");
        let _ = std::fs::remove_file(&p);
    }

    /// Satellite contract: a truncated `edges.bin` is named as truncation
    /// with the missing count, a bit-flipped one as a digest mismatch —
    /// never a silently wrong graph.
    #[test]
    fn edge_list_bin_truncation_and_corruption_are_structured_errors() {
        use crate::dist::fault::{flip_file_bit, truncate_file};
        let pairs = messy_pairs(50, 200, 32);
        let p = tmp("elbin_fault");
        write_edge_list_bin(50, &pairs, &p).unwrap();
        let len = std::fs::metadata(&p).unwrap().len();

        truncate_file(&p, len - 30).unwrap();
        let err = read_edge_list_bin(&p).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("truncated binary edge list"), "{msg}");
        assert!(msg.contains("pairs missing"), "{msg}");

        write_edge_list_bin(50, &pairs, &p).unwrap();
        flip_file_bit(&p, 40, 5).unwrap();
        let err = read_edge_list_bin(&p).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("digest mismatch"), "{msg}");

        std::fs::write(&p, b"NOTANEDGELIST___").unwrap();
        let err = read_edge_list_bin(&p).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("COFREEL1"), "found-vs-expected missing: {msg}");
        std::fs::remove_file(&p).unwrap();
    }
}
