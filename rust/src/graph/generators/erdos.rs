//! Erdős–Rényi G(n, m) generator — the simplest baseline topology, used in
//! tests and as the "no structure" control in ablations.

use crate::graph::builder::GraphBuilder;
use crate::graph::csr::Graph;
use crate::util::rng::Rng;

/// Sample a uniform graph with `n` nodes and (approximately, after dedup)
/// `m` undirected edges.
pub fn erdos_renyi(n: usize, m: usize, rng: &mut Rng) -> Graph {
    assert!(n >= 2 || m == 0, "need at least two nodes to place edges");
    let mut b = GraphBuilder::new(n);
    // Oversample slightly to counter dedup losses, then trim at build.
    let mut placed = 0usize;
    let mut attempts = 0usize;
    let max_attempts = m.saturating_mul(4) + 16;
    while placed < m && attempts < max_attempts {
        let u = rng.below(n) as u32;
        let v = rng.below(n) as u32;
        attempts += 1;
        if u != v {
            b.edge(u, v);
            placed += 1;
        }
    }
    b.edges(&[]).build()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sizes_are_close() {
        let mut rng = Rng::new(1);
        let g = erdos_renyi(1000, 5000, &mut rng);
        assert_eq!(g.num_nodes(), 1000);
        // Dedup can only shrink, and for n=1000, m=5000 collisions are rare.
        assert!(g.num_edges() > 4800 && g.num_edges() <= 5000, "m={}", g.num_edges());
        g.check_invariants().unwrap();
    }

    #[test]
    fn deterministic() {
        let g1 = erdos_renyi(100, 300, &mut Rng::new(7));
        let g2 = erdos_renyi(100, 300, &mut Rng::new(7));
        assert_eq!(g1.edges(), g2.edges());
    }
}
