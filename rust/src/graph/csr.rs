//! Compressed-sparse-row representation of an undirected graph.
//!
//! The graph is stored symmetrically (every undirected edge appears in both
//! adjacency lists) plus a canonical edge list `edges[k] = (u, v)` with
//! `u < v`, which is what the Vertex Cut partitioners operate on: a vertex
//! cut assigns every *canonical* edge to exactly one partition.

/// Undirected graph in CSR form. Node ids are dense `0..n`.
#[derive(Clone, Debug)]
pub struct Graph {
    /// CSR row offsets, length `n + 1`.
    offsets: Vec<u32>,
    /// Concatenated (symmetric) adjacency lists, length `2 * m`.
    targets: Vec<u32>,
    /// Canonical undirected edges, `u < v`, sorted lexicographically.
    edges: Vec<(u32, u32)>,
}

impl Graph {
    /// Build from CSR parts; callers normally use [`crate::graph::builder::GraphBuilder`].
    pub(crate) fn from_parts(offsets: Vec<u32>, targets: Vec<u32>, edges: Vec<(u32, u32)>) -> Self {
        debug_assert!(!offsets.is_empty());
        debug_assert_eq!(*offsets.last().unwrap() as usize, targets.len());
        debug_assert_eq!(targets.len(), edges.len() * 2);
        Graph { offsets, targets, edges }
    }

    /// Build the symmetric CSR from a canonical edge list that is already
    /// sorted lexicographically, deduplicated and self-loop free (`u < v`
    /// for every edge). This is the shared fast path of the partitioning
    /// pipeline ([`crate::graph::builder::GraphBuilder::build`], vertex-cut
    /// materialization, HEP's cold subgraph): two counting passes and one
    /// scatter, **no per-row sort**. Row `v` comes out sorted because its
    /// smaller neighbors are scattered in ascending order (counting-sort of
    /// the edges by second endpoint) into the row prefix, and its larger
    /// neighbors are a contiguous ascending run of the edge list copied into
    /// the row suffix.
    pub(crate) fn from_sorted_edges(n: usize, edges: Vec<(u32, u32)>) -> Graph {
        debug_assert!(edges.windows(2).all(|w| w[0] < w[1]), "edges must be sorted + unique");
        debug_assert!(edges.iter().all(|&(u, v)| u < v && (v as usize) < n));
        let m = edges.len();
        // deg_hi[v]: neighbors greater than v; deg_lo[v]: neighbors smaller.
        let mut deg_lo = vec![0u32; n];
        let mut deg_hi = vec![0u32; n];
        for &(u, v) in &edges {
            deg_hi[u as usize] += 1;
            deg_lo[v as usize] += 1;
        }
        let mut offsets = vec![0u32; n + 1];
        for i in 0..n {
            offsets[i + 1] = offsets[i] + deg_lo[i] + deg_hi[i];
        }
        // Counting-sort the edges by second endpoint: back[in_off[v]..in_off[v+1]]
        // lists v's smaller neighbors ascending (the scan preserves order).
        let mut in_off = vec![0u32; n + 1];
        for i in 0..n {
            in_off[i + 1] = in_off[i] + deg_lo[i];
        }
        let mut back = vec![0u32; m];
        let mut cursor = in_off[..n].to_vec();
        for &(u, v) in &edges {
            back[cursor[v as usize] as usize] = u;
            cursor[v as usize] += 1;
        }
        // Forward runs: edges[e_off[v]..e_off[v+1]] are v's larger neighbors.
        let mut e_off = vec![0u32; n + 1];
        for i in 0..n {
            e_off[i + 1] = e_off[i] + deg_hi[i];
        }
        let mut targets = vec![0u32; 2 * m];
        for v in 0..n {
            let row = &mut targets[offsets[v] as usize..offsets[v + 1] as usize];
            let lo = deg_lo[v] as usize;
            row[..lo].copy_from_slice(&back[in_off[v] as usize..in_off[v + 1] as usize]);
            let fwd = &edges[e_off[v] as usize..e_off[v + 1] as usize];
            for (slot, &(_, w)) in row[lo..].iter_mut().zip(fwd) {
                *slot = w;
            }
        }
        Graph::from_parts(offsets, targets, edges)
    }

    /// Number of nodes.
    #[inline]
    pub fn num_nodes(&self) -> usize {
        self.offsets.len() - 1
    }

    /// Number of undirected edges.
    #[inline]
    pub fn num_edges(&self) -> usize {
        self.edges.len()
    }

    /// Degree of node `v` (number of distinct neighbors).
    #[inline]
    pub fn degree(&self, v: u32) -> u32 {
        self.offsets[v as usize + 1] - self.offsets[v as usize]
    }

    /// Neighbors of `v`, sorted ascending.
    #[inline]
    pub fn neighbors(&self, v: u32) -> &[u32] {
        &self.targets[self.offsets[v as usize] as usize..self.offsets[v as usize + 1] as usize]
    }

    /// Canonical edge list (`u < v`, lexicographically sorted).
    #[inline]
    pub fn edges(&self) -> &[(u32, u32)] {
        &self.edges
    }

    /// All degrees as a vector.
    pub fn degrees(&self) -> Vec<u32> {
        (0..self.num_nodes() as u32).map(|v| self.degree(v)).collect()
    }

    /// Average degree `2m / n`.
    pub fn avg_degree(&self) -> f64 {
        if self.num_nodes() == 0 {
            0.0
        } else {
            2.0 * self.num_edges() as f64 / self.num_nodes() as f64
        }
    }

    /// Maximum degree.
    pub fn max_degree(&self) -> u32 {
        (0..self.num_nodes() as u32).map(|v| self.degree(v)).max().unwrap_or(0)
    }

    /// Minimum degree (0 if the graph has isolated nodes).
    pub fn min_degree(&self) -> u32 {
        (0..self.num_nodes() as u32).map(|v| self.degree(v)).min().unwrap_or(0)
    }

    /// True if the edge `(u, v)` exists (binary search on the adjacency row).
    pub fn has_edge(&self, u: u32, v: u32) -> bool {
        self.neighbors(u).binary_search(&v).is_ok()
    }

    /// Number of isolated (degree-0) nodes.
    pub fn num_isolated(&self) -> usize {
        (0..self.num_nodes() as u32).filter(|&v| self.degree(v) == 0).count()
    }

    /// Verify structural invariants; used by tests and after deserialization.
    pub fn check_invariants(&self) -> anyhow::Result<()> {
        use anyhow::ensure;
        let n = self.num_nodes() as u32;
        ensure!(self.offsets[0] == 0, "offsets must start at 0");
        for w in self.offsets.windows(2) {
            ensure!(w[0] <= w[1], "offsets must be non-decreasing");
        }
        ensure!(
            *self.offsets.last().unwrap() as usize == self.targets.len(),
            "offsets must end at targets.len()"
        );
        ensure!(self.targets.len() == 2 * self.edges.len(), "symmetric storage");
        for v in 0..n {
            let row = self.neighbors(v);
            for w in row.windows(2) {
                ensure!(w[0] < w[1], "adjacency rows must be strictly sorted (node {v})");
            }
            for &t in row {
                ensure!(t < n, "target out of range");
                ensure!(t != v, "self loop at {v}");
            }
        }
        for (i, &(u, v)) in self.edges.iter().enumerate() {
            ensure!(u < v, "edge {i} not canonical");
            ensure!(v < n, "edge {i} endpoint out of range");
            ensure!(self.has_edge(u, v) && self.has_edge(v, u), "edge {i} missing from CSR");
            if i > 0 {
                ensure!(self.edges[i - 1] < (u, v), "edges not sorted/unique at {i}");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use crate::graph::builder::GraphBuilder;

    fn triangle_plus_tail() -> super::Graph {
        // 0-1, 1-2, 0-2, 2-3
        GraphBuilder::new(4).edges(&[(0, 1), (1, 2), (0, 2), (2, 3)]).build()
    }

    #[test]
    fn basic_accessors() {
        let g = triangle_plus_tail();
        assert_eq!(g.num_nodes(), 4);
        assert_eq!(g.num_edges(), 4);
        assert_eq!(g.degree(0), 2);
        assert_eq!(g.degree(2), 3);
        assert_eq!(g.degree(3), 1);
        assert_eq!(g.neighbors(2), &[0, 1, 3]);
        assert!(g.has_edge(0, 2));
        assert!(g.has_edge(2, 0));
        assert!(!g.has_edge(0, 3));
        assert_eq!(g.max_degree(), 3);
        assert_eq!(g.min_degree(), 1);
        assert!((g.avg_degree() - 2.0).abs() < 1e-12);
        g.check_invariants().unwrap();
    }

    #[test]
    fn isolated_nodes_counted() {
        let g = GraphBuilder::new(5).edges(&[(0, 1)]).build();
        assert_eq!(g.num_isolated(), 3);
        g.check_invariants().unwrap();
    }
}
