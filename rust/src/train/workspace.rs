//! The per-worker workspace arena: every buffer the steady-state epoch
//! hot loop touches, allocated **once** at engine setup and reused for the
//! life of the worker.
//!
//! Before this arena existed, one native train step heap-allocated every
//! intermediate — per-layer activations, aggregates, denominators, the
//! logits gradient, backward scratch matrices and the gradient tensors
//! themselves — some `4·L + 8` fresh `Vec`s per partition per epoch.
//! [`ModelWorkspace`] owns all of them at their exact padded sizes, and it
//! is **shape-driven**: the buffer list comes from the model's
//! [`layer_plans`](crate::train::model::GnnModel::layer_plans) and
//! [`scratch_widths`](crate::train::model::GnnModel::scratch_widths), so
//! one arena type serves every [`ModelKind`](crate::train::model::ModelKind)
//! — Sage keeps per-layer messages/aggregates/denominators, GCN keeps
//! combined inputs + denominators, GIN keeps combined inputs + MLP hidden
//! rows. The per-model `forward_into` / `loss_grad_into` / `backward_into`
//! kernels overwrite the buffers in place, and the engine reuses its
//! epoch-level scratch (`selected`, `picks`, the `TrainOut` slots) the same
//! way, so a steady-state epoch performs **zero heap allocations** for
//! every model kind. That claim is a test, not a comment:
//! `tests/alloc_steady.rs` installs a counting global allocator and asserts
//! the allocation count of a training run is independent of the epoch
//! count — once per `ModelKind`.
//!
//! The arena is plain data — no interior mutability. Each `CpuWorker`
//! wraps its workspace in a `Mutex` (uncontended: every worker is visited
//! exactly once per epoch) so `run_workers` can fill workspaces from a
//! `&self` rayon loop.

use crate::runtime::{ModelConfig, TrainOut};
use crate::train::model::GnnModel;

/// All per-step temporaries of one native train step for one padded batch
/// of `n` rows, preallocated at the exact sizes the model's layer recipe
/// dictates. Buffers a model does not use are left at length 0.
///
/// Buffer lifetimes across one `train_step_into`:
///
/// * forward fills the per-layer buffers (`outs[l]` always; `msgs`/`aggs`/
///   `combs`/`denoms` per the model's plan);
/// * the loss writes the logits gradient into the front of `dbuf_a` and
///   the per-node partials into `per_node`;
/// * backward reads the current upstream gradient from `dbuf_a`, runs the
///   model's scatter/GEMM chain through the scratch buffers, writes the
///   next layer's input gradient into `dbuf_b`, then ping-pongs the two
///   `dbuf`s — a pointer swap, never a copy.
pub struct ModelWorkspace {
    /// Padded row count this workspace was sized for.
    pub n: usize,
    /// `outs[l]` = output of layer `l` (`[n, hidden]`, last `[n, classes]`).
    pub outs: Vec<Vec<f32>>,
    /// Hidden activations per layer: Sage post-ReLU messages, GIN MLP
    /// hidden rows (`[n, hidden]`); unused (empty) for GCN.
    pub msgs: Vec<Vec<f32>>,
    /// Raw aggregated neighbor values per layer (Sage only).
    pub aggs: Vec<Vec<f32>>,
    /// Combined pre-GEMM inputs per layer (GCN `agg + h/ĉ`, GIN
    /// `(1+ε)h + Σ`); unused (empty) for Sage.
    pub combs: Vec<Vec<f32>>,
    /// Per-node aggregation denominators per layer (Sage mean, GCN `ĉ`).
    pub denoms: Vec<Vec<f32>>,
    /// Per-node `(weighted loss, weight, correct)` partials of the loss.
    pub per_node: Vec<(f64, f64, f64)>,
    /// Upstream-gradient ping buffer, `[n, max(hidden, classes)]`. Holds
    /// the logits gradient when backward starts.
    pub dbuf_a: Vec<f32>,
    /// Upstream-gradient pong buffer, same size as `dbuf_a`.
    pub dbuf_b: Vec<f32>,
    /// Scratch: Sage gradient into the aggregation half of the concat;
    /// GCN/GIN gradient w.r.t. the combined input (`dcomb`).
    pub dagg: Vec<f32>,
    /// Scratch: Sage/GIN gradient w.r.t. hidden activations; GCN scatter
    /// output.
    pub dmsg: Vec<f32>,
    /// Scratch for the second addend of the input gradient.
    pub dh_msg: Vec<f32>,
}

impl ModelWorkspace {
    /// Allocate every buffer the `cfg` model's layer recipe needs over `n`
    /// padded rows.
    pub fn new(cfg: &ModelConfig, n: usize) -> ModelWorkspace {
        let model = GnnModel::new(cfg);
        let plans = model.layer_plans();
        let mut outs = Vec::with_capacity(plans.len());
        let mut msgs = Vec::with_capacity(plans.len());
        let mut aggs = Vec::with_capacity(plans.len());
        let mut combs = Vec::with_capacity(plans.len());
        let mut denoms = Vec::with_capacity(plans.len());
        for p in &plans {
            outs.push(vec![0f32; n * p.out_w]);
            msgs.push(vec![0f32; n * p.msg_w]);
            aggs.push(vec![0f32; n * p.agg_w]);
            combs.push(vec![0f32; n * p.comb_w]);
            denoms.push(vec![0f32; if p.needs_denom { n } else { 0 }]);
        }
        let sw = model.scratch_widths();
        ModelWorkspace {
            n,
            outs,
            msgs,
            aggs,
            combs,
            denoms,
            per_node: vec![(0.0, 0.0, 0.0); n],
            dbuf_a: vec![0f32; n * sw.dbuf],
            dbuf_b: vec![0f32; n * sw.dbuf],
            dagg: vec![0f32; n * sw.dagg],
            dmsg: vec![0f32; n * sw.dmsg],
            dh_msg: vec![0f32; n * sw.dh_msg],
        }
    }

    /// The logits of the last completed forward pass.
    pub fn logits(&self) -> &[f32] {
        self.outs.last().expect("forward_into ran")
    }

    /// Total bytes held by the arena's buffers. Buffers are sized once in
    /// [`ModelWorkspace::new`] and never grown, so this is also the peak —
    /// the number workers report over the wire (protocol v5) and the run
    /// ledger records per rank.
    pub fn bytes(&self) -> u64 {
        let f32s = |vs: &[Vec<f32>]| vs.iter().map(|v| v.len()).sum::<usize>();
        let flat = f32s(&self.outs)
            + f32s(&self.msgs)
            + f32s(&self.aggs)
            + f32s(&self.combs)
            + f32s(&self.denoms)
            + self.dbuf_a.len()
            + self.dbuf_b.len()
            + self.dagg.len()
            + self.dmsg.len()
            + self.dh_msg.len();
        (flat * std::mem::size_of::<f32>()
            + self.per_node.len() * std::mem::size_of::<(f64, f64, f64)>()) as u64
    }
}

/// Size `out`'s gradient tensors to the model's parameter layout without
/// reallocating when they already match (the steady-state case). The
/// values are left untouched — `backward_into` overwrites every element.
///
/// This runs once per train step inside the zero-allocation steady state,
/// so it walks the parameter lengths through the allocation-free
/// [`GnnModel::for_each_param_len`] visitor instead of materializing
/// `param_shapes()` (which builds named specs) on every call.
pub fn ensure_grad_shapes(cfg: &ModelConfig, out: &mut TrainOut) {
    let model = GnnModel::new(cfg);
    let count = model.num_param_tensors();
    if out.grads.len() != count {
        out.grads.resize_with(count, Vec::new);
    }
    let mut idx = 0usize;
    model.for_each_param_len(|len| {
        let g = &mut out.grads[idx];
        if g.len() != len {
            g.resize(len, 0.0);
        }
        idx += 1;
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::train::model::ModelKind;

    #[test]
    fn sage_workspace_sizes_match_model() {
        let cfg =
            ModelConfig { kind: ModelKind::Sage, layers: 3, feat_dim: 6, hidden: 8, classes: 4 };
        let ws = ModelWorkspace::new(&cfg, 32);
        assert_eq!(ws.outs.len(), 3);
        assert_eq!(ws.outs[0].len(), 32 * 8);
        assert_eq!(ws.outs[2].len(), 32 * 4);
        assert_eq!(ws.msgs[1].len(), 32 * 8);
        assert_eq!(ws.denoms[0].len(), 32);
        assert_eq!(ws.dbuf_a.len(), 32 * 8);
        assert_eq!(ws.per_node.len(), 32);
        // Sage has no combined-input buffers.
        assert!(ws.combs.iter().all(|c| c.is_empty()));
    }

    #[test]
    fn gcn_workspace_follows_the_plan() {
        let cfg =
            ModelConfig { kind: ModelKind::Gcn, layers: 2, feat_dim: 6, hidden: 8, classes: 4 };
        let ws = ModelWorkspace::new(&cfg, 16);
        // comb width is the layer INPUT width: feat_dim then hidden.
        assert_eq!(ws.combs[0].len(), 16 * 6);
        assert_eq!(ws.combs[1].len(), 16 * 8);
        // One layer-invariant ĉ buffer (layer 0), shared by every layer.
        assert_eq!(ws.denoms[0].len(), 16);
        assert!(ws.denoms[1].is_empty());
        assert!(ws.msgs.iter().all(|m| m.is_empty()));
        assert!(ws.aggs.iter().all(|a| a.is_empty()));
        assert_eq!(ws.dagg.len(), 16 * 8);
        assert_eq!(ws.dh_msg.len(), 0);
    }

    #[test]
    fn gin_workspace_follows_the_plan() {
        let cfg =
            ModelConfig { kind: ModelKind::Gin, layers: 2, feat_dim: 12, hidden: 8, classes: 4 };
        let ws = ModelWorkspace::new(&cfg, 16);
        assert_eq!(ws.combs[0].len(), 16 * 12);
        assert_eq!(ws.msgs[0].len(), 16 * 8);
        assert!(ws.denoms.iter().all(|d| d.is_empty()));
        // dcomb scratch must fit the widest layer input (feat_dim here).
        assert_eq!(ws.dagg.len(), 16 * 12);
    }

    #[test]
    fn ensure_grad_shapes_is_idempotent_and_preserves_allocations() {
        for kind in ModelKind::ALL {
            let cfg = ModelConfig { kind, layers: 2, feat_dim: 6, hidden: 8, classes: 4 };
            let mut out =
                TrainOut { loss_sum: 0.0, weight_sum: 0.0, correct: 0.0, grads: Vec::new() };
            ensure_grad_shapes(&cfg, &mut out);
            assert_eq!(out.grads.len(), cfg.param_shapes().len());
            for (g, s) in out.grads.iter().zip(cfg.param_shapes()) {
                assert_eq!(g.len(), s.iter().product::<usize>());
            }
            let ptrs: Vec<*const f32> = out.grads.iter().map(|g| g.as_ptr()).collect();
            ensure_grad_shapes(&cfg, &mut out);
            let ptrs2: Vec<*const f32> = out.grads.iter().map(|g| g.as_ptr()).collect();
            assert_eq!(ptrs, ptrs2, "second sizing must not reallocate ({kind:?})");
        }
    }
}
