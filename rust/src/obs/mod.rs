//! Observability: the measurement substrate for the performance claims.
//!
//! The paper's headline is a *performance* number, so the runtime has to be
//! able to say where an epoch's time went — per phase, per rank — without
//! perturbing the thing it measures. Three pieces, all dependency-free:
//!
//! * [`metrics`] — a process-global, lock-light registry of named counters,
//!   gauges and fixed-bucket histograms. Handles are `&'static`; updates
//!   are single atomic ops, so instrumented code stays allocation-free in
//!   the steady state (`tests/alloc_steady.rs` proves it with telemetry
//!   enabled).
//! * [`trace`] — cheap begin/end spans into preallocated per-thread ring
//!   buffers (drop-oldest on overflow, surfaced as a counter), exported as
//!   Chrome trace-event JSON (`cofree train --trace-out trace.json`, open
//!   in Perfetto / `chrome://tracing`). The coordinator and each worker
//!   rank map to distinct pids.
//! * [`ledger`] — the structured run ledger (`--metrics-out m.jsonl`): one
//!   durable JSON line per epoch plus a final run-summary record, written
//!   with the durable-write helpers so a crashed run still leaves a
//!   parseable artifact.
//!
//! The hard rule, shared with the wire protocol's determinism contract:
//! telemetry reads clocks and atomics only — it never draws RNG, never
//! reorders a float op — so the training trajectory is bit-identical with
//! or without it (`tests/dist_proc.rs` asserts this over real processes).

pub mod ledger;
pub mod metrics;
pub mod trace;

pub use ledger::{append_summary, Ledger};
pub use trace::{span, Span};
