//! Runtime: loading and executing the AOT-compiled XLA artifacts.
//!
//! The Python side (`python/compile/aot.py`) lowers the GraphSAGE
//! `train_step` / `eval_step` per *shape bucket* to HLO text under
//! `artifacts/`; this module loads those files through the PJRT C API
//! (`xla` crate), compiles them once per process, and exposes typed
//! execute calls. Python never runs here.

pub mod artifact;
pub mod buffers;
pub mod client;
pub mod executor;

pub use artifact::{ArtifactKind, ArtifactSpec, ModelConfig, Registry};
pub use buffers::{Tensor, TensorData};
pub use client::RuntimeClient;
pub use executor::{EvalOut, Executor, ParamSet, TrainOut};
