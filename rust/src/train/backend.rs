//! The backend abstraction: one training loop, many execution substrates.
//!
//! [`crate::train::engine::TrainEngine`] implements Algorithm 1 once — worker
//! selection, DropEdge-K mask picks, gradient all-reduce, optimizer step,
//! metrics — and drives the per-partition `train_step` through this trait.
//! Two backends implement it:
//!
//! * [`crate::train::cpu::CpuBackend`] — the native pure-Rust GraphSAGE
//!   forward/backward (cache-blocked rayon SGEMM + CSR segment
//!   aggregation). Default features; workers run in parallel on the host,
//!   demonstrating communication-free parallelism in-process.
//! * `XlaBackend` (`--features xla`) — the AOT-compiled PJRT artifacts.
//!
//! Determinism contract: [`Backend::run_workers`] must return outputs in
//! `selected` order and every implementation must be bit-stable under any
//! thread count; the engine then folds gradients sequentially in that order,
//! so the summed gradient (and the whole training trajectory) is identical
//! whether workers ran serially, on 2 threads, or on 64.

use super::tensorize::{EvalBatch, TrainBatch};
use crate::runtime::{ArtifactKind, ModelConfig, ParamSet, TrainOut};
use crate::util::rng::Rng;
use anyhow::Result;

/// Host-side per-worker metadata the engine keeps for loss normalization and
/// accuracy denominators (so the trait needs no accessor methods).
#[derive(Clone, Copy, Debug, Default)]
pub struct WorkerMeta {
    /// `Σ_j tmask_j · dar_j` of the worker's batch.
    pub local_train_weight: f64,
    /// `Σ_j tmask_j` (train-accuracy denominator).
    pub tmask_sum: f64,
    /// Size of the worker's DropEdge-K mask bank (0 = no DropEdge).
    pub num_masks: usize,
}

/// An execution substrate for the communication-free training loop.
pub trait Backend {
    /// Per-partition prepared state (device buffers, CSR indexes, …).
    type Worker;
    /// Prepared full-graph evaluation state.
    type Eval;

    /// Short stable identifier (`"cpu"`, `"xla"`).
    fn name(&self) -> &'static str;

    /// Padded `(n_pad, e_pad)` shape for a batch needing `n_need` nodes and
    /// `e_need` *directed* edges. The PJRT backend answers from its artifact
    /// registry; the native backend rounds to the quantum ladder.
    fn bucket(
        &mut self,
        model: &ModelConfig,
        kind: ArtifactKind,
        n_need: usize,
        e_need: usize,
    ) -> Result<(usize, usize)>;

    /// Prepare one worker from its tensorized batch (uploads / index
    /// construction / DropEdge-K mask bank generation happen here, once).
    fn prepare_worker(
        &mut self,
        model: &ModelConfig,
        batch: TrainBatch,
        dropedge: Option<(usize, f64)>,
        rng: &mut Rng,
    ) -> Result<Self::Worker>;

    /// Prepare full-graph evaluation state.
    fn prepare_eval(&mut self, model: &ModelConfig, batch: EvalBatch) -> Result<Self::Eval>;

    /// Execute `train_step` on `workers[selected[i]]` with DropEdge mask
    /// `picks[i]` for every `i`, writing `(TrainOut, compute_seconds)` into
    /// `outs[i]` (in `selected` order). `outs` is an engine-owned scratch
    /// vector handed back on every call: implementations must size it to
    /// `selected.len()` while **reusing** the existing slots — and the
    /// gradient tensors inside them — so a steady-state epoch allocates
    /// nothing (the native backend and the proc transport do; the arena
    /// contract is asserted by `tests/alloc_steady.rs`). Implementations
    /// are free to run the workers in parallel (the native backend does,
    /// via rayon); `compute_seconds` is each worker's own wall-clock, the
    /// `compute_i` in the reported parallel-machine iteration time
    /// `max_i(compute_i) + allreduce`.
    /// Timing caveat: when workers share one host (the native backend),
    /// concurrent workers contend for cores, so `compute_seconds` is an
    /// *upper bound* on each worker's dedicated-machine compute — honest
    /// for wall-clock comparisons on this host, conservative for Table-1
    /// style projections. The PJRT backend times workers sequentially and
    /// has no such inflation.
    fn run_workers(
        &self,
        workers: &[Self::Worker],
        selected: &[usize],
        picks: &[Option<usize>],
        params: &ParamSet,
        outs: &mut Vec<(TrainOut, f64)>,
    ) -> Result<()>;

    /// Accuracy on a split (0 train, 1 val, 2 test) of a prepared eval setup.
    fn evaluate(&self, eval: &Self::Eval, params: &ParamSet, split: usize) -> Result<f64>;

    /// `(val, test)` accuracy in one call. Backends whose forward pass does
    /// not depend on the split (the native backend) override this to run
    /// the full-graph forward once and score both masks; the default just
    /// evaluates twice (the PJRT artifact takes the mask as a device input,
    /// so two executions is its natural shape).
    fn evaluate_val_test(&self, eval: &Self::Eval, params: &ParamSet) -> Result<(f64, f64)> {
        Ok((self.evaluate(eval, params, 1)?, self.evaluate(eval, params, 2)?))
    }
}
