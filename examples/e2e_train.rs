//! End-to-end validation driver (DESIGN.md / EXPERIMENTS.md §E2E).
//!
//! Trains GraphSAGE on the products-sim dataset with the full CoFree-GNN
//! stack — NE vertex cut, DAR reweighting, DropEdge-K, AOT HLO artifacts on
//! PJRT — for several hundred epochs, in both the full-graph and the
//! 4-partition communication-free configuration, logging loss curves and
//! accuracy to results/e2e_*.csv. This proves all three layers compose on a
//! real workload.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_train
//! ```

use cofree_gnn::graph::datasets;
use cofree_gnn::partition::{algorithm, PartitionMetrics, Reweighting, VertexCut};
use cofree_gnn::train::engine::{TrainConfig, TrainEngine};
use cofree_gnn::util::rng::Rng;
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let epochs: usize = std::env::var("E2E_EPOCHS").ok().and_then(|v| v.parse().ok()).unwrap_or(300);
    let ds = datasets::build("products-sim", 0.25, 42)?;
    println!(
        "e2e: products-sim scale 0.25 — n={} m={} d={} C={} | GraphSAGE {}x{}",
        ds.graph.num_nodes(),
        ds.graph.num_edges(),
        ds.data.dim,
        ds.data.num_classes,
        ds.layers,
        ds.hidden
    );
    let mut engine = TrainEngine::new(Path::new("artifacts"))?;
    let eval = engine.prepare_eval(&ds)?;
    let cfg = TrainConfig {
        epochs,
        lr: 0.01,
        eval_every: 10,
        log_every: (epochs / 15).max(1),
        ..Default::default()
    };

    // Full-graph baseline.
    println!("\n== full-graph training ==");
    let mut full = engine.prepare_full(&ds, None, 0)?;
    let (h_full, _, t_full) = engine.train(&mut full, Some(&eval), &cfg)?;
    h_full.write_csv(Path::new("results/e2e_full.csv"))?;

    // CoFree-GNN, 4 partitions, DAR + DropEdge-K.
    println!("\n== CoFree-GNN (p=4, NE, DAR, DropEdge-K=10@0.5) ==");
    let mut rng = Rng::new(42);
    let vc = VertexCut::create(&ds.graph, 4, algorithm("ne").unwrap().as_ref(), &mut rng);
    println!("partition: {}", PartitionMetrics::vertex_cut(&ds.graph, &vc).row());
    let mut part = engine.prepare_partitions(&ds, &vc, Reweighting::Dar, Some((10, 0.5)), 0)?;
    let (h_part, _, t_part) = engine.train(&mut part, Some(&eval), &cfg)?;
    h_part.write_csv(Path::new("results/e2e_cofree.csv"))?;

    // Summary.
    let (fv, ft) = h_full.best();
    let (pv, pt) = h_part.best();
    let (fms, _) = h_full.iter_time_ms(2);
    let (pms, _) = h_part.iter_time_ms(2);
    println!("\n== e2e summary ({epochs} epochs) ==");
    println!("full-graph : best val {fv:.4} test {ft:.4}  iter {fms:.1} ms   [{}]", t_full.report());
    println!("cofree p=4 : best val {pv:.4} test {pt:.4}  iter {pms:.1} ms   [{}]", t_part.report());
    println!("loss curves -> results/e2e_full.csv, results/e2e_cofree.csv");
    anyhow::ensure!(pv > 0.5, "CoFree run failed to learn");
    anyhow::ensure!((fv - pv).abs() < 0.1, "accuracy gap too large: {fv} vs {pv}");
    println!("e2e OK");
    Ok(())
}
