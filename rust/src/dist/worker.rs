//! The `cofree worker` role: one process, one shard, zero graph knowledge
//! beyond its own partition.
//!
//! A worker streams its shard from disk, connects to the coordinator,
//! prepares its partition exactly the way the in-process engine would —
//! same padded bucket ([`pad_explicit`]), same tensorization, same
//! DropEdge-K mask bank drawn from the same forked RNG stream
//! ([`worker_mask_rng`], the single definition `prepare_partitions` also
//! uses) — and then answers `Step` frames with `StepResult`s until the
//! coordinator says `Shutdown`. Because every input bit and every RNG
//! draw matches the in-process path, the `TrainOut` it returns is
//! bit-identical to what the same partition would have produced inside
//! the coordinator's address space.

use super::proto::{self, Frame, Stream, PROTO_VERSION};
use super::shard::Shard;
use crate::runtime::ParamSet;
use crate::train::bucket::pad_explicit;
use crate::train::cpu::{self, EdgeCsr};
use crate::train::dropedge::MaskBank;
use crate::train::engine::worker_mask_rng;
use anyhow::{bail, ensure, Context, Result};
use std::path::Path;
use std::time::Instant;

/// Run the worker loop to completion. Returns the number of train steps
/// served.
pub fn run(shard_path: &Path, connect: &str) -> Result<usize> {
    let shard = Shard::read(shard_path)
        .with_context(|| format!("loading shard {}", shard_path.display()))?;
    let rank = shard.part_id;
    crate::log_info!(
        "worker rank {rank}/{}: shard {} (n_local={}, m_local={}), connecting to {connect}",
        shard.num_parts,
        shard_path.display(),
        shard.global_ids.len(),
        shard.local.num_edges()
    );
    let mut stream = Stream::connect(connect)?;
    proto::write_frame(
        &mut stream,
        &Frame::Hello {
            proto_version: PROTO_VERSION,
            rank: rank as u32,
            num_parts: shard.num_parts as u32,
        },
    )?;
    let (frame, _) = proto::read_frame(&mut stream)?;
    let Frame::Config { seed, dropedge_k, dropedge_ratio, model } = frame else {
        bail!("expected Config frame after Hello, got {frame:?}");
    };
    ensure!(
        model == shard.model,
        "coordinator model {model:?} does not match shard model {:?}",
        shard.model
    );

    // Prepare the partition exactly like TrainEngine::prepare_partitions +
    // CpuBackend::prepare_worker would have.
    let (n_pad, e_pad) = pad_explicit(shard.local.num_nodes(), 2 * shard.local.num_edges());
    let batch = shard.tensorize(n_pad, e_pad).context("tensorizing shard")?;
    let csr = EdgeCsr::from_batch(&batch);
    let masks = if dropedge_k > 0 {
        let mut rng = worker_mask_rng(seed, rank);
        MaskBank::generate(&batch, dropedge_k as usize, dropedge_ratio, &mut rng).masks
    } else {
        Vec::new()
    };
    proto::write_frame(
        &mut stream,
        &Frame::Meta {
            local_train_weight: batch.local_train_weight,
            tmask_sum: batch.tmask_sum(),
            num_masks: masks.len() as u32,
        },
    )?;

    let dims = model.param_shapes();
    let mut steps = 0usize;
    loop {
        let (frame, _) = proto::read_frame(&mut stream)?;
        match frame {
            Frame::Step { pick, params } => {
                ensure!(params.len() == dims.len(), "expected {} param tensors, got {}", dims.len(), params.len());
                for (i, (p, shape)) in params.iter().zip(&dims).enumerate() {
                    let want: usize = shape.iter().product();
                    ensure!(p.len() == want, "param tensor {i}: {} elements, expected {want}", p.len());
                }
                let params = ParamSet { dims: dims.clone(), data: params };
                let emask = match pick {
                    Some(k) => {
                        ensure!(k < masks.len(), "mask pick {k} out of range {}", masks.len());
                        masks[k].as_f32()
                    }
                    None => batch.emask().as_f32(),
                };
                let t0 = Instant::now();
                let out = cpu::train_step(&shard.model, &params, &batch, &csr, emask);
                let compute_seconds = t0.elapsed().as_secs_f64();
                proto::write_frame(&mut stream, &Frame::StepResult { out, compute_seconds })?;
                steps += 1;
            }
            Frame::Shutdown => {
                crate::log_info!("worker rank {rank}: shutdown after {steps} steps");
                return Ok(steps);
            }
            other => bail!("unexpected frame in step loop: {other:?}"),
        }
    }
}
