//! Partitioning-pipeline benchmark: graph build → vertex-cut assign →
//! materialization, old (pre-PR sequential) vs new (parallel,
//! allocation-lean) paths, on R-MAT and Chung–Lu graphs.
//!
//! Run: `cargo bench --bench bench_partition`. Knobs (environment):
//! * `COFREE_BENCH_EDGES` — target raw edge count (default 10_000_000)
//! * `COFREE_BENCH_ITERS` — timing repetitions per phase (default 2)
//! * `COFREE_BENCH_PARTS` — partition count (default 8)
//! * `COFREE_BENCH_ALGOS` — comma list of vertex cuts (default `greedy,hep`)
//! * `COFREE_BENCH_OUT`   — output JSON path (default `BENCH_partition.json`)
//! * `COFREE_BENCH_OOC_EDGES` / `COFREE_BENCH_OOC_BUDGET_MIB` — raw pair
//!   count (default `edges/10`) and memory budget (default 4 MiB) of the
//!   out-of-core ingest section
//!
//! Emits `BENCH_partition.json` so the perf trajectory is tracked in-repo:
//! per graph and per algorithm, old/new seconds and speedups for build,
//! assign, materialize and end-to-end, plus a bit-identity check of the
//! materialized partitions across rayon pool sizes 1/2/8. The "old" sides
//! are the retained pre-PR implementations (`build_reference`,
//! `from_assignment_reference`, and frozen copies of the pre-PR greedy/HEP
//! inner loops below), so the comparison stays honest as the fast paths
//! evolve. An `out_of_core` section times `ingest::stream_shards` end to
//! end at a fixed budget and asserts byte-parity with the in-memory store.

use cofree_gnn::dist;
use cofree_gnn::graph::generators::{chung_lu_pairs, power_law_degrees, rmat_pairs, RmatParams};
use cofree_gnn::graph::{Dataset, Graph, GraphBuilder};
use cofree_gnn::ingest::{self, SliceSource, StreamAlgo, StreamDataset, StreamOptions};
use cofree_gnn::partition::{algorithm, dar_weights, Reweighting, VertexCut};
use cofree_gnn::util::rng::Rng;
use std::fmt::Write as _;
use std::time::Instant;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn env_string(key: &str, default: &str) -> String {
    std::env::var(key).unwrap_or_else(|_| default.to_string())
}

/// Time `f` `iters` times; returns (mean seconds, last result).
fn timed<T>(iters: usize, mut f: impl FnMut() -> T) -> (f64, T) {
    assert!(iters >= 1);
    let mut total = 0.0;
    let mut out = None;
    for _ in 0..iters {
        let t0 = Instant::now();
        out = Some(std::hint::black_box(f()));
        total += t0.elapsed().as_secs_f64();
    }
    (total / iters as f64, out.unwrap())
}

#[inline]
fn fnv(h: &mut u64, x: u64) {
    *h ^= x;
    *h = h.wrapping_mul(0x0000_0100_0000_01B3);
}

/// FNV-1a over a graph's full structure (edges + every adjacency row).
fn fingerprint_graph(g: &Graph) -> u64 {
    let mut h = 0xCBF2_9CE4_8422_2325u64;
    fnv(&mut h, g.num_nodes() as u64);
    for &(u, v) in g.edges() {
        fnv(&mut h, ((u as u64) << 32) | v as u64);
    }
    for v in 0..g.num_nodes() as u32 {
        for &w in g.neighbors(v) {
            fnv(&mut h, w as u64);
        }
    }
    h
}

/// FNV-1a over a vertex cut's full structure (assignment, id tables, local
/// CSRs). Equal fingerprints ⇒ byte-identical cuts.
fn fingerprint_vc(vc: &VertexCut) -> u64 {
    let mut h = 0xCBF2_9CE4_8422_2325u64;
    for &a in &vc.assignment {
        fnv(&mut h, a as u64);
    }
    for part in &vc.parts {
        fnv(&mut h, part.part_id as u64);
        for &gid in &part.global_ids {
            fnv(&mut h, gid as u64);
        }
        fnv(&mut h, fingerprint_graph(&part.local));
    }
    h
}

/// Frozen pre-PR implementations, kept verbatim so "old" timings do not
/// silently improve as the library's shared fast paths evolve.
mod pre_pr {
    use cofree_gnn::graph::{Graph, GraphBuilder};
    use cofree_gnn::partition::ne::NeighborExpansion;
    use cofree_gnn::partition::VertexCutAlgorithm;
    use cofree_gnn::util::rng::Rng;

    /// Pre-PR PowerGraph greedy: materializes host-set `Vec`s per edge.
    pub fn greedy_assign(g: &Graph, p: usize, rng: &mut Rng) -> Vec<u32> {
        let m = g.num_edges();
        let n = g.num_nodes();
        let mut order: Vec<u32> = (0..m as u32).collect();
        rng.shuffle(&mut order);
        let use_bits = p <= 64;
        let mut abits = vec![0u64; if use_bits { n } else { 0 }];
        let mut avec: Vec<Vec<u32>> = if use_bits { Vec::new() } else { vec![Vec::new(); n] };
        let mut load = vec![0usize; p];
        let mut out = vec![0u32; m];
        let hosts = |abits: &[u64], avec: &[Vec<u32>], v: usize| -> Vec<u32> {
            if use_bits {
                let mut b = abits[v];
                let mut out = Vec::new();
                while b != 0 {
                    let i = b.trailing_zeros();
                    out.push(i);
                    b &= b - 1;
                }
                out
            } else {
                avec[v].clone()
            }
        };
        for &k in &order {
            let (u, v) = g.edges()[k as usize];
            let hu = hosts(&abits, &avec, u as usize);
            let hv = hosts(&abits, &avec, v as usize);
            let least = |cands: &[u32], load: &[usize]| -> u32 {
                *cands.iter().min_by_key(|&&c| load[c as usize]).unwrap()
            };
            let common: Vec<u32> = hu.iter().copied().filter(|c| hv.contains(c)).collect();
            let choice = if !common.is_empty() {
                least(&common, &load)
            } else if !hu.is_empty() && !hv.is_empty() {
                let pick = if g.degree(u) >= g.degree(v) { &hu } else { &hv };
                least(pick, &load)
            } else if !hu.is_empty() {
                least(&hu, &load)
            } else if !hv.is_empty() {
                least(&hv, &load)
            } else {
                (0..p as u32).min_by_key(|&c| load[c as usize]).unwrap()
            };
            out[k as usize] = choice;
            load[choice as usize] += 1;
            if use_bits {
                abits[u as usize] |= 1 << choice;
                abits[v as usize] |= 1 << choice;
            } else {
                for &node in &[u, v] {
                    let a = &mut avec[node as usize];
                    if let Err(pos) = a.binary_search(&choice) {
                        a.insert(pos, choice);
                    }
                }
            }
        }
        out
    }

    /// Pre-PR HEP: clones the cold edge list twice (pairs for the builder,
    /// (u, v, k) triples for the sort-based back-mapping) and re-sorts it
    /// through the sequential `GraphBuilder` path.
    pub fn hep_assign(g: &Graph, p: usize, tau: f64, rng: &mut Rng) -> Vec<u32> {
        let m = g.num_edges();
        if p == 1 {
            return vec![0; m];
        }
        let threshold = (tau * g.avg_degree()).max(1.0) as u32;
        let salt = rng.next_u64();
        let hash = |x: u32| -> u32 {
            let mut z = (salt ^ x as u64).wrapping_add(0x9E37_79B9_7F4A_7C15);
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            ((z ^ (z >> 31)) % p as u64) as u32
        };
        let mut assign = vec![u32::MAX; m];
        let mut cold_edges: Vec<u32> = Vec::new();
        for (k, &(u, v)) in g.edges().iter().enumerate() {
            let (du, dv) = (g.degree(u), g.degree(v));
            let low = du.min(dv);
            if low > threshold {
                let key = if du < dv || (du == dv && u < v) { u } else { v };
                assign[k] = hash(key);
            } else {
                cold_edges.push(k as u32);
            }
        }
        if !cold_edges.is_empty() {
            let sub_pairs: Vec<(u32, u32)> =
                cold_edges.iter().map(|&k| g.edges()[k as usize]).collect();
            let sub = GraphBuilder::new(g.num_nodes()).edges(&sub_pairs).build_reference();
            let mut sorted_cold: Vec<(u32, u32, u32)> = cold_edges
                .iter()
                .map(|&k| {
                    let (u, v) = g.edges()[k as usize];
                    (u, v, k)
                })
                .collect();
            sorted_cold.sort_unstable();
            let ne = NeighborExpansion::default();
            let sub_assign = ne.assign(&sub, p, rng);
            for (i, &(_, _, k)) in sorted_cold.iter().enumerate() {
                assign[k as usize] = sub_assign[i];
            }
        }
        assign
    }
}

struct PhaseTimes {
    old_s: f64,
    new_s: f64,
}

impl PhaseTimes {
    fn speedup(&self) -> f64 {
        self.old_s / self.new_s.max(1e-12)
    }
    fn json(&self) -> String {
        format!(
            "{{\"old_s\": {:.6}, \"new_s\": {:.6}, \"speedup\": {:.3}}}",
            self.old_s,
            self.new_s,
            self.speedup()
        )
    }
}

struct AlgoResult {
    name: String,
    assign: PhaseTimes,
    materialize: PhaseTimes,
    end_to_end: PhaseTimes,
    assign_has_frozen_old: bool,
    identical_across_threads: bool,
}

fn main() {
    let target = env_usize("COFREE_BENCH_EDGES", 10_000_000);
    let iters = env_usize("COFREE_BENCH_ITERS", 2);
    let p = env_usize("COFREE_BENCH_PARTS", 8);
    let algo_list = env_string("COFREE_BENCH_ALGOS", "greedy,hep");
    let out_path = env_string("COFREE_BENCH_OUT", "BENCH_partition.json");
    let algos: Vec<&str> = algo_list.split(',').map(|s| s.trim()).filter(|s| !s.is_empty()).collect();

    println!("== bench_partition: build -> assign -> materialize ==");
    println!(
        "target_edges={target} iters={iters} p={p} algos={algos:?} rayon_threads={}",
        rayon::current_num_threads()
    );

    let mut graph_jsons: Vec<String> = Vec::new();

    let specs: [(&str, u64); 2] = [("rmat", 0xA11CE), ("chung-lu", 0xB0B)];
    for (family, seed) in specs {
        // --- Raw edge stream -------------------------------------------------
        let mut rng = Rng::new(seed);
        let (n, pairs) = match family {
            "rmat" => {
                let scale = ((target / 10).max(2) as f64).log2().ceil() as u32;
                (1usize << scale, rmat_pairs(scale, target, RmatParams::default(), &mut rng))
            }
            _ => {
                let n = (target / 6).max(64);
                let w = power_law_degrees(n, 2.2, 4, 1000, &mut rng.fork(1));
                (n, chung_lu_pairs(&w, &mut rng.fork(2)))
            }
        };
        println!("\n-- {family}: n={n}, raw pairs={} --", pairs.len());

        // --- Build phase -----------------------------------------------------
        let (build_old_s, g_old) =
            timed(iters, || GraphBuilder::new(n).edges(&pairs).build_reference());
        let (build_new_s, g) = timed(iters, || GraphBuilder::new(n).edges(&pairs).build());
        assert_eq!(
            fingerprint_graph(&g_old),
            fingerprint_graph(&g),
            "{family}: parallel build diverged from reference"
        );
        drop(g_old);
        let build = PhaseTimes { old_s: build_old_s, new_s: build_new_s };
        println!(
            "build          old {:>8.3}s  new {:>8.3}s  ({:.2}x)   m={}",
            build.old_s,
            build.new_s,
            build.speedup(),
            g.num_edges()
        );

        // --- Per-algorithm assign + materialize ------------------------------
        let mut algo_results: Vec<AlgoResult> = Vec::new();
        for &name in &algos {
            let algo = match algorithm(name) {
                Some(a) => a,
                None => {
                    eprintln!("unknown algorithm {name:?}, skipping");
                    continue;
                }
            };
            let (assign_new_s, assignment) =
                timed(iters, || algo.assign(&g, p, &mut Rng::new(7)));
            let (assign_old_s, frozen) = match name {
                "greedy" => {
                    let (t, a_old) =
                        timed(iters, || pre_pr::greedy_assign(&g, p, &mut Rng::new(7)));
                    assert_eq!(
                        a_old, assignment,
                        "{family}: new greedy diverged from pre-PR reference"
                    );
                    (t, true)
                }
                "hep" => {
                    let (t, a_old) =
                        timed(iters, || pre_pr::hep_assign(&g, p, 4.0, &mut Rng::new(7)));
                    assert_eq!(
                        a_old, assignment,
                        "{family}: new hep diverged from pre-PR reference"
                    );
                    (t, true)
                }
                // No frozen pre-PR copy: the algorithm's inner loop was not
                // rewritten, so old ≈ new by construction.
                _ => (timed(iters, || algo.assign(&g, p, &mut Rng::new(7))).0, false),
            };

            let (mat_old_s, vc_old) = timed(iters, || {
                VertexCut::from_assignment_reference(&g, p, assignment.clone())
            });
            let (mat_new_s, vc_new) =
                timed(iters, || VertexCut::from_assignment(&g, p, assignment.clone()));
            let fp = fingerprint_vc(&vc_new);
            assert_eq!(
                fingerprint_vc(&vc_old),
                fp,
                "{family}/{name}: fast materialization diverged from reference"
            );
            drop(vc_old);
            drop(vc_new);

            // Bit-identity across rayon pool sizes.
            let mut identical = true;
            for threads in [1usize, 2, 8] {
                let pool =
                    rayon::ThreadPoolBuilder::new().num_threads(threads).build().unwrap();
                let vc =
                    pool.install(|| VertexCut::from_assignment(&g, p, assignment.clone()));
                if fingerprint_vc(&vc) != fp {
                    eprintln!("{family}/{name}: output differs at {threads} threads!");
                    identical = false;
                }
            }

            let res = AlgoResult {
                name: name.to_string(),
                assign: PhaseTimes { old_s: assign_old_s, new_s: assign_new_s },
                materialize: PhaseTimes { old_s: mat_old_s, new_s: mat_new_s },
                end_to_end: PhaseTimes {
                    old_s: build.old_s + assign_old_s + mat_old_s,
                    new_s: build.new_s + assign_new_s + mat_new_s,
                },
                assign_has_frozen_old: frozen,
                identical_across_threads: identical,
            };
            println!(
                "{name:<8} assign old {:>8.3}s new {:>8.3}s ({:.2}x) | materialize old {:>8.3}s new {:>8.3}s ({:.2}x) | e2e {:.2}x | threads-identical={}",
                res.assign.old_s,
                res.assign.new_s,
                res.assign.speedup(),
                res.materialize.old_s,
                res.materialize.new_s,
                res.materialize.speedup(),
                res.end_to_end.speedup(),
                res.identical_across_threads
            );
            algo_results.push(res);
        }

        // --- JSON ------------------------------------------------------------
        let mut algos_json = String::new();
        for (i, r) in algo_results.iter().enumerate() {
            if i > 0 {
                algos_json.push_str(", ");
            }
            write!(
                algos_json,
                "{{\"name\": \"{}\", \"assign\": {}, \"assign_has_frozen_old\": {}, \"materialize\": {}, \"end_to_end\": {}, \"identical_across_threads\": {}}}",
                r.name,
                r.assign.json(),
                r.assign_has_frozen_old,
                r.materialize.json(),
                r.end_to_end.json(),
                r.identical_across_threads
            )
            .unwrap();
        }
        graph_jsons.push(format!(
            "{{\"name\": \"{family}\", \"nodes\": {}, \"edges\": {}, \"raw_pairs\": {}, \"build\": {}, \"algos\": [{algos_json}]}}",
            g.num_nodes(),
            g.num_edges(),
            pairs.len(),
            build.json()
        ));
    }

    // --- Out-of-core ingest ---------------------------------------------
    // Fixed memory budget, R-MAT raw stream: edges/sec through the full
    // streamed pipeline (sort → degrees → assign → materialize), spill
    // volume, merge passes, and a byte-parity assertion against the
    // in-memory store.
    let ooc_edges = env_usize("COFREE_BENCH_OOC_EDGES", (target / 10).max(20_000));
    let budget_mib = env_usize("COFREE_BENCH_OOC_BUDGET_MIB", 4);
    let ooc_scale = ((ooc_edges / 10).max(2) as f64).log2().ceil() as u32;
    let n = 1usize << ooc_scale;
    let pairs = rmat_pairs(ooc_scale, ooc_edges, RmatParams::default(), &mut Rng::new(0xD15C));
    let data = ingest::synth_node_data(n, 0xD15C);
    let tmp = std::env::temp_dir().join(format!("cofree_bench_ooc_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&tmp);
    std::fs::create_dir_all(&tmp).unwrap();
    let (mem_dir, stream_dir) = (tmp.join("mem"), tmp.join("stream"));
    let ds = Dataset {
        name: "bench-ooc".into(),
        graph: GraphBuilder::new(n).edges(&pairs).build(),
        data: data.clone(),
        layers: ingest::SYNTH_LAYERS,
        hidden: ingest::SYNTH_HIDDEN,
    };
    let dbh = algorithm("dbh").unwrap();
    let vc = VertexCut::create(&ds.graph, p, dbh.as_ref(), &mut Rng::new(0xD15C));
    let weights = dar_weights(&ds.graph, &vc, Reweighting::Dar);
    dist::write_shards(&ds, &vc, &weights, 0xD15C, &mem_dir).unwrap();
    let mut opts = StreamOptions::new(p, StreamAlgo::Dbh, Reweighting::Dar, 0xD15C);
    opts.mem_budget_bytes = (budget_mib as u64) << 20;
    let sds = StreamDataset {
        name: "bench-ooc",
        data: &data,
        layers: ingest::SYNTH_LAYERS,
        hidden: ingest::SYNTH_HIDDEN,
    };
    let t0 = Instant::now();
    let mut src = SliceSource::new(n, &pairs);
    let stats = ingest::stream_shards(&mut src, &sds, &opts, &stream_dir).unwrap();
    let ooc_s = t0.elapsed().as_secs_f64();
    let mut parity = true;
    for rec in &stats.store.files {
        parity &= std::fs::read(mem_dir.join(&rec.name)).unwrap()
            == std::fs::read(stream_dir.join(&rec.name)).unwrap();
    }
    parity &= std::fs::read(mem_dir.join("manifest.json")).unwrap()
        == std::fs::read(stream_dir.join("manifest.json")).unwrap();
    assert!(parity, "streamed store diverged from the in-memory store");
    let edges_per_sec = stats.raw_pairs as f64 / ooc_s.max(1e-9);
    println!(
        "\n-- out_of_core: {} raw pairs @ {budget_mib} MiB budget -> {:.0} edges/sec, \
         {} spill runs / {:.1} MiB, {} merge passes, parity={parity} --",
        stats.raw_pairs,
        edges_per_sec,
        stats.runs_spilled,
        stats.spill_bytes as f64 / (1024.0 * 1024.0),
        stats.merge_passes
    );
    let ooc_json = format!(
        "{{\"raw_pairs\": {}, \"edges\": {}, \"budget_mib\": {budget_mib}, \"seconds\": {:.6}, \"edges_per_sec\": {:.1}, \"spill_bytes\": {}, \"runs_spilled\": {}, \"merge_passes\": {}, \"parity\": {parity}}}",
        stats.raw_pairs,
        stats.edges,
        ooc_s,
        edges_per_sec,
        stats.spill_bytes,
        stats.runs_spilled,
        stats.merge_passes
    );
    let _ = std::fs::remove_dir_all(&tmp);

    let json = format!(
        "{{\n  \"bench\": \"partition_pipeline\",\n  \"config\": {{\"edges_target\": {target}, \"partitions\": {p}, \"iters\": {iters}}},\n  \"machine\": {{\"logical_cpus\": {}, \"rayon_threads\": {}}},\n  \"out_of_core\": {ooc_json},\n  \"graphs\": [\n    {}\n  ]\n}}\n",
        std::thread::available_parallelism().map(|x| x.get()).unwrap_or(1),
        rayon::current_num_threads(),
        graph_jsons.join(",\n    ")
    );
    std::fs::write(&out_path, &json).expect("writing bench JSON");
    println!("\nwrote {out_path}");
}
