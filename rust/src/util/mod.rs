//! Small shared utilities: deterministic RNG, logging, timing helpers, and
//! the shared binary codecs every on-disk/on-wire format is built from.

pub mod binio;
pub mod half;
pub mod hash;
pub mod json;
pub mod logging;
pub mod mmap;
pub mod rng;
pub mod timer;

/// Round `n` up to the next power of two, with a floor.
pub fn next_pow2_at_least(n: usize, floor: usize) -> usize {
    let n = n.max(floor).max(1);
    n.next_power_of_two()
}

/// Mean and (population) standard deviation of a slice.
pub fn mean_std(xs: &[f64]) -> (f64, f64) {
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    let mean = xs.iter().sum::<f64>() / xs.len() as f64;
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
    (mean, var.sqrt())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pow2_rounding() {
        assert_eq!(next_pow2_at_least(0, 16), 16);
        assert_eq!(next_pow2_at_least(16, 16), 16);
        assert_eq!(next_pow2_at_least(17, 16), 32);
        assert_eq!(next_pow2_at_least(1000, 1), 1024);
    }

    #[test]
    fn mean_std_basic() {
        let (m, s) = mean_std(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        assert!((m - 5.0).abs() < 1e-12);
        assert!((s - 2.0).abs() < 1e-12);
        let (m0, s0) = mean_std(&[]);
        assert_eq!((m0, s0), (0.0, 0.0));
    }
}
