"""Layer-2: the GraphSAGE model, DAR-weighted loss, and train/eval steps.

This is the *compute graph* that every CoFree-GNN worker executes on its own
vertex-cut partition.  It is written in JAX, calls the Layer-1 Pallas kernels
(``kernels.matmul``) for the dense hot spots, and is lowered ONCE by
``aot.py`` into HLO text that the Rust coordinator loads through PJRT.
Python never runs during training.

Tensor conventions (shared contract with ``rust/src/train/tensorize.rs``):

* graphs arrive as *directed message edge lists*: ``src[e] -> dst[e]``; the
  Rust side emits both directions of every undirected edge, pads to
  ``e_pad`` with ``emask=0`` entries, and pads nodes to ``n_pad`` rows with
  ``dar_w = train_mask = 0``;
* ``dar_w`` carries the Degree-Aware Reweighting weight
  ``D(v[i]) / D(v)`` of the paper's Eq. 3 (or 1 / 1/RF for the ablations);
* the train step returns the *sum* (not mean) of weighted losses plus its
  gradients, so the leader can sum partition gradients (DAR makes that sum
  approximate the full-graph gradient, Thm 4.3) and normalize once by the
  global number of training nodes.

Parameter layout per layer ``l`` (order matters — Rust mirrors it):
``W_l [in, H]``, ``b_l [H]``, ``U_l [H + in, out]``, ``c_l [out]`` with
``in = feat_dim`` for ``l = 0`` else ``H``; ``out = classes`` for the last
layer else ``H``.
"""

import jax
import jax.numpy as jnp

from .kernels import matmul as pk
from .kernels import ref


def param_shapes(layers: int, feat_dim: int, hidden: int, classes: int):
    """Shapes of the flat parameter list (mirrored by the Rust runtime)."""
    shapes = []
    for l in range(layers):
        d_in = feat_dim if l == 0 else hidden
        d_out = classes if l == layers - 1 else hidden
        shapes.append((d_in, hidden))       # W_l
        shapes.append((hidden,))            # b_l
        shapes.append((hidden + d_in, d_out))  # U_l
        shapes.append((d_out,))             # c_l
    return shapes


def init_params(seed: int, layers: int, feat_dim: int, hidden: int, classes: int):
    """Glorot-uniform initialization, deterministic in ``seed``."""
    key = jax.random.PRNGKey(seed)
    params = []
    for shape in param_shapes(layers, feat_dim, hidden, classes):
        if len(shape) == 1:
            params.append(jnp.zeros(shape, jnp.float32))
        else:
            key, sub = jax.random.split(key)
            fan_in, fan_out = shape
            lim = (6.0 / (fan_in + fan_out)) ** 0.5
            params.append(jax.random.uniform(sub, shape, jnp.float32, -lim, lim))
    return params


def forward(params, feat, src, dst, emask, *, layers, use_pallas=True):
    """GraphSAGE forward pass over one (padded) partition -> logits [N, C]."""
    n = feat.shape[0]
    mm = pk.matmul if use_pallas else ref.matmul_ref
    rl = pk.relu_linear if use_pallas else ref.relu_linear_ref
    h = feat
    for l in range(layers):
        w, b, u, c = params[4 * l : 4 * l + 4]
        msg = rl(h, w, b)                       # [N, H]  message transform
        agg = ref.segment_mean_ref(msg[src], dst, emask, n)  # neighbor mean
        h = mm(jnp.concatenate([agg, h], axis=1), u) + c
    return h


def _weighted_ce(logits, labels, weights):
    """Sum of ``weights[j] * CE(logits[j], labels[j])`` plus the weight sum."""
    logz = jax.nn.logsumexp(logits, axis=1)
    picked = jnp.take_along_axis(logits, labels[:, None], axis=1)[:, 0]
    ce = logz - picked
    return jnp.sum(weights * ce), jnp.sum(weights)


def make_train_step(layers: int, use_pallas: bool = True):
    """Build ``train_step(params..., data...) -> (loss_sum, weight_sum,
    correct, *grads)`` for a fixed layer count (static for lowering)."""

    def loss_fn(params, feat, src, dst, emask, dar_w, labels, train_mask):
        logits = forward(params, feat, src, dst, emask, layers=layers, use_pallas=use_pallas)
        weights = dar_w * train_mask
        loss_sum, weight_sum = _weighted_ce(logits, labels, weights)
        correct = jnp.sum(train_mask * (jnp.argmax(logits, axis=1) == labels))
        return loss_sum, (weight_sum, correct)

    def train_step(params, feat, src, dst, emask, dar_w, labels, train_mask):
        (loss_sum, (weight_sum, correct)), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, feat, src, dst, emask, dar_w, labels, train_mask
        )
        return (
            loss_sum.reshape(1),
            weight_sum.reshape(1),
            correct.reshape(1).astype(jnp.float32),
            *grads,
        )

    return train_step


def make_eval_step(layers: int, use_pallas: bool = True):
    """Build ``eval_step(params..., data..., mask) -> (correct, count,
    loss_sum)`` — run by the leader on the full graph for val/test metrics."""

    def eval_step(params, feat, src, dst, emask, labels, mask):
        logits = forward(params, feat, src, dst, emask, layers=layers, use_pallas=use_pallas)
        correct = jnp.sum(mask * (jnp.argmax(logits, axis=1) == labels))
        loss_sum, _ = _weighted_ce(logits, labels, mask)
        return (
            correct.reshape(1).astype(jnp.float32),
            jnp.sum(mask).reshape(1),
            loss_sum.reshape(1),
        )

    return eval_step
