//! Model checkpointing: serialize/restore parameters + optimizer state.
//!
//! `cofree train --save-model m.bin` writes a [`TrainCheckpoint`] after
//! training; `--load-model m.bin` restores it and continues, and the
//! continued trajectory is **bit-identical** to an uninterrupted run of the
//! same total length (the engine replays the epoch-level RNG draws for the
//! already-completed epochs, so DropEdge picks and Rotate selections line
//! up — see `TrainEngine::train_resumable`).
//!
//! The file format reuses the shard store's header/versioning helpers
//! ([`crate::util::binio`]): magic + u32 version, then little-endian
//! length-prefixed tensors. All f32 payloads round-trip bit-exactly.

use crate::runtime::{ModelConfig, ParamSet};
use crate::train::model::ModelKind;
use crate::train::optimizer::{Optimizer, OptimizerState};
use crate::util::binio;
use anyhow::{bail, ensure, Context, Result};
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::{Path, PathBuf};
use std::sync::mpsc;

pub const CHECKPOINT_MAGIC: &[u8; 8] = b"COFREECK";
/// Version 2 added the model-kind tag to the header (the `GnnModel`
/// refactor): a checkpoint records WHICH architecture its parameters
/// belong to, not just the dims, so loading a GCN checkpoint into a Sage
/// run fails loudly instead of misindexing tensors.
pub const CHECKPOINT_VERSION: u32 = 2;

/// A resumable training state: how many epochs are done, the parameters,
/// and the optimizer's internal state.
#[derive(Clone, Debug)]
pub struct TrainCheckpoint {
    /// Number of epochs already completed when this state was taken.
    pub epochs_done: usize,
    /// Model the parameters belong to (validated on resume).
    pub model: ModelConfig,
    pub params: ParamSet,
    pub opt: OptimizerState,
}

fn write_param_list(w: &mut impl Write, data: &[Vec<f32>]) -> Result<()> {
    binio::write_u32(w, data.len() as u32)?;
    for t in data {
        binio::write_f32s(w, t)?;
    }
    Ok(())
}

fn read_param_list(r: &mut impl Read) -> Result<Vec<Vec<f32>>> {
    let k = binio::read_u32(r)? as usize;
    ensure!(k <= 4096, "corrupt checkpoint: {k} tensors");
    (0..k).map(|_| binio::read_f32s(r)).collect()
}

impl TrainCheckpoint {
    /// Serialize to `path`. Returns the number of bytes written.
    pub fn save(&self, path: &Path) -> Result<u64> {
        let f = std::fs::File::create(path).with_context(|| format!("create {path:?}"))?;
        let mut w = BufWriter::new(f);
        binio::write_magic(&mut w, CHECKPOINT_MAGIC)?;
        binio::write_version(&mut w, CHECKPOINT_VERSION)?;
        binio::write_u64(&mut w, self.epochs_done as u64)?;
        binio::write_u8(&mut w, self.model.kind.code())?;
        for d in [self.model.layers, self.model.feat_dim, self.model.hidden, self.model.classes] {
            binio::write_u32(&mut w, d as u32)?;
        }
        // Parameter dims then data (dims are re-derivable from the model but
        // stored anyway so a reader can validate without model code).
        binio::write_u32(&mut w, self.params.dims.len() as u32)?;
        for dims in &self.params.dims {
            binio::write_u32(&mut w, dims.len() as u32)?;
            for &d in dims {
                binio::write_u64(&mut w, d as u64)?;
            }
        }
        write_param_list(&mut w, &self.params.data)?;
        match &self.opt {
            OptimizerState::Sgd => binio::write_u8(&mut w, 0)?,
            OptimizerState::Adam { t, m, v } => {
                binio::write_u8(&mut w, 1)?;
                binio::write_u64(&mut w, *t as u64)?;
                write_param_list(&mut w, m)?;
                write_param_list(&mut w, v)?;
            }
        }
        w.flush()?;
        let bytes = std::fs::metadata(path)?.len();
        Ok(bytes)
    }

    /// Deserialize from `path`, validating magic, version and shape
    /// consistency.
    pub fn load(path: &Path) -> Result<TrainCheckpoint> {
        let f = std::fs::File::open(path).with_context(|| format!("open {path:?}"))?;
        let mut r = BufReader::new(f);
        binio::expect_magic(&mut r, CHECKPOINT_MAGIC, "cofree model checkpoint")
            .with_context(|| format!("reading {path:?}"))?;
        binio::expect_version(&mut r, CHECKPOINT_VERSION, "model checkpoint")?;
        let epochs_done = binio::read_u64(&mut r)? as usize;
        let kind = ModelKind::from_code(binio::read_u8(&mut r)?)
            .context("reading checkpoint model kind")?;
        let model = ModelConfig {
            kind,
            layers: binio::read_u32(&mut r)? as usize,
            feat_dim: binio::read_u32(&mut r)? as usize,
            hidden: binio::read_u32(&mut r)? as usize,
            classes: binio::read_u32(&mut r)? as usize,
        };
        let k = binio::read_u32(&mut r)? as usize;
        ensure!(k <= 4096, "corrupt checkpoint: {k} parameter tensors");
        let mut dims = Vec::with_capacity(k);
        for _ in 0..k {
            let rank = binio::read_u32(&mut r)? as usize;
            ensure!(rank <= 8, "corrupt checkpoint: rank {rank}");
            let mut shape = Vec::with_capacity(rank);
            for _ in 0..rank {
                shape.push(binio::read_u64(&mut r)? as usize);
            }
            dims.push(shape);
        }
        let data = read_param_list(&mut r)?;
        ensure!(
            dims.len() == data.len(),
            "checkpoint dims/data arity mismatch: {} vs {}",
            dims.len(),
            data.len()
        );
        for (i, (shape, d)) in dims.iter().zip(&data).enumerate() {
            let want: usize = shape.iter().product();
            ensure!(d.len() == want, "checkpoint tensor {i}: {} elements, dims say {want}", d.len());
        }
        ensure!(
            dims == model.param_shapes(),
            "checkpoint parameter shapes do not match its model config"
        );
        let opt = match binio::read_u8(&mut r)? {
            0 => OptimizerState::Sgd,
            1 => {
                let t = binio::read_u64(&mut r)? as i32;
                let m = read_param_list(&mut r)?;
                let v = read_param_list(&mut r)?;
                ensure!(
                    m.len() == data.len() && v.len() == data.len(),
                    "adam moment arity does not match parameters"
                );
                OptimizerState::Adam { t, m, v }
            }
            other => bail!("unknown optimizer kind tag {other} in checkpoint"),
        };
        Ok(TrainCheckpoint { epochs_done, model, params: ParamSet { dims, data }, opt })
    }
}

// ---------------------------------------------------------------------------
// Periodic async checkpointing.
// ---------------------------------------------------------------------------

/// Periodic checkpoint writer that stays off the epoch hot loop.
///
/// `cofree train --checkpoint ck.bin --checkpoint-every N` snapshots
/// training state every N epochs so a crashed run resumes from the last
/// snapshot instead of epoch 0 (and, because `train_resumable` replays the
/// epoch-level RNG draws, the resumed trajectory is **bit-identical** to
/// an uninterrupted run — `tests/chaos.rs`).
///
/// Design constraints, in order:
///
/// 1. **Never block the epoch loop on disk.** Serialization + I/O happen
///    on a dedicated writer thread; [`offer`](AsyncCheckpointer::offer)
///    only copies tensors into a pre-owned snapshot buffer.
/// 2. **Never allocate in steady state.** Two snapshot buffers ping-pong
///    between the trainer and the writer over channels; after the first
///    two fills, `Vec::clone_from` (and
///    [`Optimizer::export_state_into`]) reuse their allocations. The
///    4-vs-24-epoch fixed point in `tests/alloc_steady.rs` holds with
///    checkpointing enabled.
/// 3. **Never leave a torn file.** Each snapshot writes to a sibling tmp
///    file and atomically renames over the target, so the file at
///    `path` is always a complete, loadable checkpoint.
///
/// If the writer is still busy with the previous snapshot when the next
/// one is due, the epoch is **skipped** (counted, not waited for) — a
/// slow disk degrades checkpoint freshness, not training throughput.
pub struct AsyncCheckpointer {
    /// Filled snapshots travel to the writer…
    jobs: mpsc::Sender<Box<TrainCheckpoint>>,
    /// …and drained buffers come back for reuse.
    slots: mpsc::Receiver<Box<TrainCheckpoint>>,
    writer: std::thread::JoinHandle<Result<usize>>,
    /// Snapshots skipped because the writer was still busy.
    skipped: usize,
}

impl AsyncCheckpointer {
    /// Start the writer thread targeting `path`.
    pub fn spawn(path: PathBuf) -> AsyncCheckpointer {
        let (job_tx, job_rx) = mpsc::channel::<Box<TrainCheckpoint>>();
        let (slot_tx, slot_rx) = mpsc::channel::<Box<TrainCheckpoint>>();
        // Prime the pool: two buffers means the trainer can fill one while
        // the writer drains the other. They start empty; the first two
        // offers size them and every later offer reuses that memory.
        for _ in 0..2 {
            let empty = TrainCheckpoint {
                epochs_done: 0,
                model: ModelConfig {
                    kind: ModelKind::Sage,
                    layers: 0,
                    feat_dim: 0,
                    hidden: 0,
                    classes: 0,
                },
                params: ParamSet { dims: Vec::new(), data: Vec::new() },
                opt: OptimizerState::Sgd,
            };
            slot_tx.send(Box::new(empty)).expect("receiver alive");
        }
        let writer = std::thread::Builder::new()
            .name("cofree-ckpt".into())
            .spawn(move || -> Result<usize> {
                let tmp = tmp_sibling(&path);
                let mut written = 0usize;
                while let Ok(snap) = job_rx.recv() {
                    snap.save(&tmp).with_context(|| format!("writing checkpoint {tmp:?}"))?;
                    std::fs::rename(&tmp, &path)
                        .with_context(|| format!("renaming {tmp:?} -> {path:?}"))?;
                    crate::log_debug!(
                        "checkpoint: epoch {} -> {}",
                        snap.epochs_done,
                        path.display()
                    );
                    written += 1;
                    // Hand the buffer back; if the trainer is gone
                    // (finish/abort), just drop it.
                    let _ = slot_tx.send(snap);
                }
                Ok(written)
            })
            .expect("spawning checkpoint writer thread");
        AsyncCheckpointer { jobs: job_tx, slots: slot_rx, writer, offered: 0, skipped: 0 }
    }

    /// Offer a snapshot of the current training state. Returns immediately:
    /// if no drained buffer is available (writer busy), the snapshot is
    /// skipped and counted, never waited for.
    pub fn offer(
        &mut self,
        epochs_done: usize,
        model: &ModelConfig,
        params: &ParamSet,
        opt: &dyn Optimizer,
    ) {
        let mut snap = match self.slots.try_recv() {
            Ok(s) => s,
            Err(_) => {
                self.skipped += 1;
                crate::log_debug!(
                    "checkpoint: writer busy, skipping snapshot at epoch {epochs_done}"
                );
                return;
            }
        };
        snap.epochs_done = epochs_done;
        snap.model = *model;
        snap.params.dims.clone_from(&params.dims);
        snap.params.data.clone_from(&params.data);
        opt.export_state_into(&mut snap.opt);
        // Send cannot fail while the writer thread holds the receiver; a
        // panicked writer surfaces in finish().
        let _ = self.jobs.send(snap);
    }

    /// Close the channel, wait for the writer to drain its queue, and
    /// return `(written, skipped)`. Propagates any write error.
    pub fn finish(self) -> Result<(usize, usize)> {
        drop(self.jobs);
        drop(self.slots);
        let written = match self.writer.join() {
            Ok(r) => r?,
            Err(_) => bail!("checkpoint writer thread panicked"),
        };
        Ok((written, self.skipped))
    }
}

fn tmp_sibling(path: &Path) -> PathBuf {
    let mut name = path.file_name().map(|s| s.to_os_string()).unwrap_or_default();
    name.push(".tmp");
    path.with_file_name(name)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("cofree_ckpt_{name}_{}", std::process::id()))
    }

    fn sample_kind(kind: ModelKind) -> TrainCheckpoint {
        let model = ModelConfig { kind, layers: 2, feat_dim: 6, hidden: 8, classes: 4 };
        let params = ParamSet::init_glorot(&model, &mut Rng::new(3));
        let m = params.data.iter().map(|d| d.iter().map(|x| x * 0.5).collect()).collect();
        let v = params.data.iter().map(|d| d.iter().map(|x| x * x).collect()).collect();
        TrainCheckpoint { epochs_done: 7, model, params, opt: OptimizerState::Adam { t: 7, m, v } }
    }

    fn sample() -> TrainCheckpoint {
        sample_kind(ModelKind::Sage)
    }

    /// Round-trips (Adam moments included) for every model kind: the
    /// header records the kind and it survives save → load bit-exactly.
    #[test]
    fn roundtrip_is_bit_exact_for_every_kind() {
        for kind in ModelKind::ALL {
            let ck = sample_kind(kind);
            let p = tmp(kind.name());
            let bytes = ck.save(&p).unwrap();
            assert!(bytes > 0);
            let got = TrainCheckpoint::load(&p).unwrap();
            assert_eq!(got.epochs_done, ck.epochs_done);
            assert_eq!(got.model, ck.model);
            assert_eq!(got.model.kind, kind);
            assert_eq!(got.params.dims, ck.params.dims);
            assert_eq!(got.params.data, ck.params.data);
            assert_eq!(got.opt, ck.opt);
            std::fs::remove_file(&p).unwrap();
        }
    }

    /// The kinds' parameter layouts really differ (so a kind mismatch can
    /// never alias silently), and the engine-side mismatch check has both
    /// kinds in its message (`train_resumable` ensures `ck.model ==
    /// run.model`; see `tests/train_native.rs` for the end-to-end case).
    #[test]
    fn kind_mismatch_cannot_alias() {
        let sage = sample_kind(ModelKind::Sage);
        let gcn = sample_kind(ModelKind::Gcn);
        let gin = sample_kind(ModelKind::Gin);
        assert_ne!(sage.params.dims, gcn.params.dims);
        assert_ne!(gcn.params.dims, gin.params.dims);
        assert_ne!(sage.model, gcn.model);
    }

    #[test]
    fn sgd_state_roundtrips() {
        let mut ck = sample();
        ck.opt = OptimizerState::Sgd;
        let p = tmp("sgd");
        ck.save(&p).unwrap();
        assert_eq!(TrainCheckpoint::load(&p).unwrap().opt, OptimizerState::Sgd);
        std::fs::remove_file(&p).unwrap();
    }

    /// The async writer's final on-disk file is a complete checkpoint
    /// matching the *last* offered snapshot, and every offer is either
    /// written or counted as skipped.
    #[test]
    fn async_checkpointer_last_write_wins_and_is_loadable() {
        use crate::train::optimizer::{Adam, Optimizer};
        let path = tmp("async");
        let _ = std::fs::remove_file(&path);
        let mut ck = AsyncCheckpointer::spawn(path.clone());
        let model = ModelConfig { kind: ModelKind::Gcn, layers: 2, feat_dim: 6, hidden: 8, classes: 4 };
        let mut params = ParamSet::init_glorot(&model, &mut Rng::new(11));
        let mut opt = Adam::new(0.01);
        let grads: Vec<Vec<f32>> = params.data.iter().map(|d| vec![0.1; d.len()]).collect();
        for epoch in 1..=5 {
            opt.step(&mut params.data, &grads, 1.0);
            ck.offer(epoch, &model, &params, &opt);
        }
        let want_params = params.clone();
        let want_opt = opt.export_state();
        let (written, skipped) = ck.finish().unwrap();
        assert_eq!(written + skipped, 5, "every offer is written or skipped");
        assert!(written >= 1, "at least one snapshot must land");
        let got = TrainCheckpoint::load(&path).unwrap();
        // The writer drains in order, so the file holds the last *written*
        // offer; with no skips that is exactly epoch 5.
        assert!(got.epochs_done >= 1 && got.epochs_done <= 5);
        if skipped == 0 {
            assert_eq!(got.epochs_done, 5);
            assert_eq!(got.params.data, want_params.data);
            assert_eq!(got.opt, want_opt);
        }
        assert_eq!(got.model, model);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn wrong_magic_reports_found_vs_expected() {
        let p = tmp("bad");
        std::fs::write(&p, b"COFREEG1junkjunkjunk").unwrap();
        let err = TrainCheckpoint::load(&p).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("COFREECK") && msg.contains("COFREEG1"), "{msg}");
        std::fs::remove_file(&p).unwrap();
    }
}
