//! The leader process: CLI, configuration, the experiment grid shared by
//! `emit-bucket-spec` and the benches, and the harnesses that regenerate
//! every table and figure of the paper.

pub mod cli;
pub mod config;
pub mod experiments;
pub mod grid;
pub mod quickbench;

pub use config::Config;
pub use grid::{eval_grid, train_grid, GridEntry, BENCH_SCALE, BENCH_SEED};
