//! Communication simulation for the baseline distributed-GNN systems.
//!
//! The paper's Table 1 / Figure 2 compare CoFree-GNN against DistDGL,
//! PipeGCN and BNS-GCN on real clusters. We do not have A100s or NICs; what
//! we *do* have is (a) real measured compute times from the PJRT workers and
//! (b) the exact boundary/halo statistics of real partitions of the actual
//! graphs. The baselines' defining characteristic — per-iteration halo
//! embedding traffic proportional to boundary size — is therefore *modeled*
//! on top of measured compute, using published link characteristics (PCIe
//! 4.0 / NVLink / 100 GbE) and each system's documented communication
//! pattern. CoFree rows are fully measured (its only traffic, the gradient
//! all-reduce, is modeled with the same link model for consistency).
//!
//! DESIGN.md §2 records this substitution; `benches/table1.rs` prints which
//! cells are measured vs. modeled.

pub mod link;
pub mod methods;
pub mod timeline;
pub mod volume;

pub use link::{Cluster, LinkModel};
pub use methods::{iteration_time, IterationBreakdown, Method};
pub use volume::{BaselineVolumes, PartitionCommStats};
