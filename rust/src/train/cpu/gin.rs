//! Native GIN (Xu et al., "How Powerful are Graph Neural Networks?", 2019)
//! forward + backward over a tensorized batch.
//!
//! The layer recipe (see `train::model`):
//!
//! ```text
//! comb = (1 + ε) · h + Σ_{e→d} w_e · h_s      (sum aggregation, trainable ε)
//! h'   = relu(comb · W1 + b1) · W2 + b2       (2-layer MLP, linear output)
//! ```
//!
//! ε is a trainable scalar per layer (initialized to 0 — 1-D tensors are
//! zero-initialized by `ParamSet::init_glorot`); its gradient is the full
//! contraction `Σ_{i,j} h_{ij} · dcomb_{ij}`, folded sequentially in f64
//! so it is deterministic for any rayon pool size. The sum aggregation
//! walks the shared [`EdgeCsr`] (per-row, ascending edge-id accumulation),
//! the GEMMs run through the packed kernels in [`super::gemm`], and every
//! temporary lives in the caller-owned [`ModelWorkspace`] — the `*_into`
//! entry points allocate nothing. The naive oracle is `reference::forward`
//! (`ModelKind::Gin` arm); gradients are checked against central finite
//! differences below, ε included.

use super::gemm;
use super::sage::EdgeCsr;
use crate::runtime::{ModelConfig, ParamSet};
use crate::train::model::ModelKind;
use crate::train::workspace::ModelWorkspace;
use rayon::prelude::*;

/// Weighted sum aggregation `out[d] = Σ_{e→d} w_e · h[s]` into a
/// caller-owned buffer (no normalization — GIN's injective aggregator).
pub(crate) fn aggregate_sum_into(csr: &EdgeCsr, emask: &[f32], h: &[f32], out: &mut [f32], d_in: usize) {
    out.par_chunks_mut(d_in).enumerate().for_each(|(d, row)| {
        row.fill(0.0);
        let lo = csr.in_off[d] as usize;
        let hi = csr.in_off[d + 1] as usize;
        for idx in lo..hi {
            let w = emask[csr.in_eid[idx] as usize];
            if w == 0.0 {
                continue;
            }
            let s = csr.in_src[idx] as usize;
            let srow = &h[s * d_in..s * d_in + d_in];
            for (av, &hv) in row.iter_mut().zip(srow.iter()) {
                *av += w * hv;
            }
        }
    });
}

/// Backward of [`aggregate_sum_into`] w.r.t. `h`:
/// `out[s] = Σ_{e: src_e = s} w_e · dcomb[d]`.
pub(crate) fn scatter_sum_into(csr: &EdgeCsr, emask: &[f32], dcomb: &[f32], out: &mut [f32], d_in: usize) {
    out.par_chunks_mut(d_in).enumerate().for_each(|(s, row)| {
        row.fill(0.0);
        let lo = csr.out_off[s] as usize;
        let hi = csr.out_off[s + 1] as usize;
        for idx in lo..hi {
            let w = emask[csr.out_eid[idx] as usize];
            if w == 0.0 {
                continue;
            }
            let d = csr.out_dst[idx] as usize;
            let drow = &dcomb[d * d_in..d * d_in + d_in];
            for (dv, &gv) in row.iter_mut().zip(drow.iter()) {
                *dv += w * gv;
            }
        }
    });
}

/// Fast GIN forward pass into a caller-owned workspace; keeps every
/// intermediate needed by [`backward_into`]. Allocates nothing.
pub fn forward_into(
    cfg: &ModelConfig,
    params: &ParamSet,
    feat: &[f32],
    emask: &[f32],
    csr: &EdgeCsr,
    n: usize,
    ws: &mut ModelWorkspace,
) {
    debug_assert_eq!(cfg.kind, ModelKind::Gin);
    debug_assert_eq!(feat.len(), n * cfg.feat_dim);
    debug_assert_eq!(csr.n, n);
    debug_assert_eq!(ws.n, n);
    let h = cfg.hidden;
    let ModelWorkspace { outs, msgs, combs, .. } = ws;
    for l in 0..cfg.layers {
        let d_in = if l == 0 { cfg.feat_dim } else { cfg.hidden };
        let d_out = if l == cfg.layers - 1 { cfg.classes } else { cfg.hidden };
        let eps = params.data[5 * l][0];
        let w1 = &params.data[5 * l + 1];
        let b1 = &params.data[5 * l + 2];
        let w2 = &params.data[5 * l + 3];
        let b2 = &params.data[5 * l + 4];
        let (prev, rest) = outs.split_at_mut(l);
        let hin: &[f32] = if l == 0 { feat } else { &prev[l - 1] };
        let comb = &mut combs[l];
        aggregate_sum_into(csr, emask, hin, comb, d_in);
        // comb += (1 + ε) · h.
        let self_scale = 1.0 + eps;
        comb.par_chunks_mut(d_in).enumerate().for_each(|(i, row)| {
            let srow = &hin[i * d_in..i * d_in + d_in];
            for (cv, &hv) in row.iter_mut().zip(srow.iter()) {
                *cv += self_scale * hv;
            }
        });
        // hid = relu(comb · W1 + b1); out = hid · W2 + b2.
        let hid = &mut msgs[l];
        gemm::matmul(comb, w1, hid, n, d_in, h);
        gemm::bias_relu_rows(hid, b1, h);
        let out = &mut rest[0];
        debug_assert_eq!(out.len(), n * d_out);
        gemm::broadcast_rows(b2, out, d_out);
        gemm::matmul_acc(hid, w2, out, n, h, d_out);
    }
}

/// Backward pass into caller-owned gradient tensors
/// (`ε, W1, b1, W2, b2` per layer). Expects the logits gradient at the
/// front of `ws.dbuf_a` (as left by `loss_grad_into`). Every element of
/// `grads` is overwritten; nothing allocates.
#[allow(clippy::too_many_arguments)]
pub fn backward_into(
    cfg: &ModelConfig,
    params: &ParamSet,
    feat: &[f32],
    emask: &[f32],
    csr: &EdgeCsr,
    n: usize,
    ws: &mut ModelWorkspace,
    grads: &mut [Vec<f32>],
) {
    debug_assert_eq!(cfg.kind, ModelKind::Gin);
    debug_assert_eq!(grads.len(), params.data.len());
    let h = cfg.hidden;
    let ModelWorkspace { outs, msgs, combs, dbuf_a, dbuf_b, dagg, dmsg, dh_msg, .. } = ws;
    for l in (0..cfg.layers).rev() {
        let d_in = if l == 0 { cfg.feat_dim } else { cfg.hidden };
        let d_out = if l == cfg.layers - 1 { cfg.classes } else { cfg.hidden };
        let eps = params.data[5 * l][0];
        let w1 = &params.data[5 * l + 1];
        let w2 = &params.data[5 * l + 3];
        let hin: &[f32] = if l == 0 { feat } else { &outs[l - 1] };
        let hid = &msgs[l];
        let comb = &combs[l];
        // Layer outputs are linear, so the upstream gradient in dbuf_a is
        // already the pre-bias gradient.
        let dout = &dbuf_a[..n * d_out];
        gemm::col_sums(dout, n, d_out, &mut grads[5 * l + 4]);
        gemm::matmul_tn(hid, dout, &mut grads[5 * l + 3], n, h, d_out);
        // Through the MLP hidden ReLU.
        let dhid = &mut dmsg[..n * h];
        gemm::matmul_nt(dout, w2, dhid, n, d_out, h);
        dhid.par_chunks_mut(h).zip(hid.par_chunks(h)).for_each(|(drow, hrow)| {
            for (dv, &hv) in drow.iter_mut().zip(hrow.iter()) {
                if hv <= 0.0 {
                    *dv = 0.0;
                }
            }
        });
        gemm::col_sums(dhid, n, h, &mut grads[5 * l + 2]);
        gemm::matmul_tn(comb, dhid, &mut grads[5 * l + 1], n, d_in, h);
        // dcomb feeds both the ε gradient and (above layer 0) the input
        // gradient.
        let dcomb = &mut dagg[..n * d_in];
        gemm::matmul_nt(dhid, w1, dcomb, n, h, d_in);
        // dε = Σ_{ij} h_{ij} · dcomb_{ij}: sequential f64 fold, bit-stable
        // for any pool size.
        let mut deps = 0f64;
        for (&hv, &cv) in hin.iter().zip(dcomb.iter()) {
            deps += hv as f64 * cv as f64;
        }
        grads[5 * l][0] = deps as f32;
        if l == 0 {
            break;
        }
        // dh = (1 + ε) · dcomb + Σ_{e: s→d} w_e · dcomb[d].
        let scat = &mut dh_msg[..n * d_in];
        scatter_sum_into(csr, emask, dcomb, scat, d_in);
        {
            let dcomb_ro: &[f32] = dcomb;
            let scat_ro: &[f32] = scat;
            let self_scale = 1.0 + eps;
            let dh = &mut dbuf_b[..n * d_in];
            dh.par_chunks_mut(d_in).enumerate().for_each(|(i, row)| {
                let crow = &dcomb_ro[i * d_in..i * d_in + d_in];
                let srow = &scat_ro[i * d_in..i * d_in + d_in];
                for ((dv, &cv), &sv) in row.iter_mut().zip(crow.iter()).zip(srow.iter()) {
                    *dv = self_scale * cv + sv;
                }
            });
        }
        std::mem::swap(dbuf_a, dbuf_b);
    }
}

#[cfg(test)]
mod tests {
    use super::super::sage::loss_grad_into;
    use super::*;
    use crate::graph::features::{synthesize, FeatureParams};
    use crate::partition::testutil::graph_zoo;
    use crate::partition::{dar_weights, random::RandomVertexCut, Reweighting, VertexCut};
    use crate::train::reference;
    use crate::train::tensorize::{tensorize_partition, TrainBatch};
    use crate::util::rng::Rng;

    fn assert_close(got: &[f32], want: &[f32], tol: f32, what: &str) {
        assert_eq!(got.len(), want.len(), "{what}: length");
        for (i, (&g, &w)) in got.iter().zip(want.iter()).enumerate() {
            assert!((g - w).abs() <= tol * (1.0 + w.abs()), "{what} elem {i}: got {g}, want {w}");
        }
    }

    fn zoo_batch(gi: usize, g: &crate::graph::Graph, seed: u64) -> Option<TrainBatch> {
        let n = g.num_nodes();
        let mut rng = Rng::new(seed + gi as u64);
        let comm: Vec<u32> = (0..n).map(|i| (i % 4) as u32).collect();
        let nd = synthesize(&comm, 4, &FeatureParams { dim: 5, ..Default::default() }, &mut rng);
        let vc = VertexCut::create(g, 2, &RandomVertexCut, &mut rng);
        let w = dar_weights(g, &vc, Reweighting::Dar);
        if vc.parts[0].num_edges() == 0 {
            return None;
        }
        Some(tensorize_partition(&vc.parts[0], &nd, &w[0], 256, 2048).unwrap())
    }

    /// The fast GIN forward matches the naive reference oracle across the
    /// graph zoo and layer counts, and is bit-identical for any rayon pool
    /// size — with a nonzero ε in play so the self-scaling is exercised.
    #[test]
    fn gin_forward_matches_reference_across_zoo_and_threads() {
        for (gi, g) in graph_zoo(37).iter().enumerate() {
            let Some(batch) = zoo_batch(gi, g, 800) else { continue };
            let csr = EdgeCsr::from_batch(&batch);
            let emask = batch.emask().as_f32();
            let feat = batch.tensors[0].as_f32();
            let mut rng = Rng::new(950 + gi as u64);
            for layers in [1usize, 2, 3] {
                let cfg = ModelConfig {
                    kind: ModelKind::Gin,
                    layers,
                    feat_dim: 5,
                    hidden: 7,
                    classes: 4,
                };
                let mut params = ParamSet::init_glorot(&cfg, &mut rng.fork(layers as u64));
                for l in 0..layers {
                    params.data[5 * l][0] = 0.1 * (l as f32 + 1.0);
                }
                let want = reference::forward(&cfg, &params, &batch);
                let mut ws = ModelWorkspace::new(&cfg, batch.n_pad);
                forward_into(&cfg, &params, feat, emask, &csr, batch.n_pad, &mut ws);
                assert_close(ws.logits(), &want, 1e-4, "gin logits");
                for threads in [1usize, 2, 8] {
                    let pool =
                        rayon::ThreadPoolBuilder::new().num_threads(threads).build().unwrap();
                    let mut ws_t = ModelWorkspace::new(&cfg, batch.n_pad);
                    pool.install(|| {
                        forward_into(&cfg, &params, feat, emask, &csr, batch.n_pad, &mut ws_t)
                    });
                    assert_eq!(
                        ws_t.logits(),
                        ws.logits(),
                        "graph#{gi} layers={layers}: gin forward differs at {threads} threads"
                    );
                }
            }
        }
    }

    /// Central finite differences over every parameter tensor — the ε
    /// scalars included (their probe is the whole tensor).
    #[test]
    fn gin_backward_matches_finite_differences() {
        let mut rng = Rng::new(8);
        let g = crate::graph::generators::barabasi_albert(120, 3, &mut rng);
        let comm: Vec<u32> = (0..120).map(|i| (i % 3) as u32).collect();
        let nd = synthesize(&comm, 3, &FeatureParams { dim: 6, ..Default::default() }, &mut rng);
        let vc = VertexCut::create(&g, 2, &RandomVertexCut, &mut rng);
        let w = dar_weights(&g, &vc, Reweighting::Dar);
        let batch = tensorize_partition(&vc.parts[0], &nd, &w[0], 128, 1024).unwrap();
        let cfg =
            ModelConfig { kind: ModelKind::Gin, layers: 2, feat_dim: 6, hidden: 8, classes: 3 };
        let mut params = ParamSet::init_glorot(&cfg, &mut rng);
        params.data[0][0] = 0.2; // nonzero ε so its gradient path is real
        let csr = EdgeCsr::from_batch(&batch);
        let feat = batch.tensors[0].as_f32().to_vec();
        let emask = batch.emask().as_f32().to_vec();
        let dar = batch.tensors[4].as_f32().to_vec();
        let labels = batch.tensors[5].as_i32().to_vec();
        let tmask = batch.tensors[6].as_f32().to_vec();
        let n = batch.n_pad;
        let mut ws = ModelWorkspace::new(&cfg, n);
        let loss_of = |p: &ParamSet, ws: &mut ModelWorkspace| -> f64 {
            forward_into(&cfg, p, &feat, &emask, &csr, n, ws);
            loss_grad_into(&cfg, &dar, &labels, &tmask, n, ws).0
        };
        forward_into(&cfg, &params, &feat, &emask, &csr, n, &mut ws);
        let _ = loss_grad_into(&cfg, &dar, &labels, &tmask, n, &mut ws);
        let mut grads: Vec<Vec<f32>> =
            params.data.iter().map(|p| vec![0f32; p.len()]).collect();
        backward_into(&cfg, &params, &feat, &emask, &csr, n, &mut ws, &mut grads);
        let eps = 2e-2f32;
        let mut ws2 = ModelWorkspace::new(&cfg, n);
        let mut checked = 0usize;
        for pi in 0..params.data.len() {
            let len = params.data[pi].len();
            let step = (len / 25).max(1);
            for ei in (0..len).step_by(step) {
                let orig = params.data[pi][ei];
                params.data[pi][ei] = orig + eps;
                let lp = loss_of(&params, &mut ws2);
                params.data[pi][ei] = orig - eps;
                let lm = loss_of(&params, &mut ws2);
                params.data[pi][ei] = orig;
                let numeric = (lp - lm) / (2.0 * eps as f64);
                let analytic = grads[pi][ei] as f64;
                checked += 1;
                assert!(
                    (analytic - numeric).abs() <= 0.05 * numeric.abs().max(1.0) + 5e-3,
                    "param {pi} elem {ei}: analytic {analytic} vs numeric {numeric}"
                );
            }
        }
        assert!(checked > 20, "probe coverage too small: {checked}");
    }

    /// Zeroing every edge weight collapses the aggregation: the layer sees
    /// only `(1+ε)·h`, so padding rows (zero features) produce exactly the
    /// MLP-of-zero logits `relu(b1)·W2 + b2`.
    #[test]
    fn gin_zero_mask_collapses_to_self_term() {
        let mut rng = Rng::new(10);
        let g = crate::graph::generators::barabasi_albert(80, 2, &mut rng);
        let comm: Vec<u32> = (0..80).map(|i| (i % 3) as u32).collect();
        let nd = synthesize(&comm, 3, &FeatureParams { dim: 4, ..Default::default() }, &mut rng);
        let vc = VertexCut::create(&g, 2, &RandomVertexCut, &mut rng);
        let w = dar_weights(&g, &vc, Reweighting::Dar);
        let batch = tensorize_partition(&vc.parts[0], &nd, &w[0], 128, 1024).unwrap();
        let cfg =
            ModelConfig { kind: ModelKind::Gin, layers: 1, feat_dim: 4, hidden: 8, classes: 3 };
        let params = ParamSet::init_glorot(&cfg, &mut rng);
        let csr = EdgeCsr::from_batch(&batch);
        let zeros = vec![0f32; batch.e_pad];
        let mut ws = ModelWorkspace::new(&cfg, batch.n_pad);
        forward_into(
            &cfg,
            &params,
            batch.tensors[0].as_f32(),
            &zeros,
            &csr,
            batch.n_pad,
            &mut ws,
        );
        // b1 is zero-initialized, so relu(b1)·W2 + b2 = b2 for zero rows.
        let b2 = &params.data[4];
        for i in batch.n_used..batch.n_pad {
            for j in 0..cfg.classes {
                assert!((ws.logits()[i * cfg.classes + j] - b2[j]).abs() < 1e-6);
            }
        }
    }
}
