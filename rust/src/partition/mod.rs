//! Graph partitioning: Vertex Cut (the paper's choice) and Edge Cut (the
//! baseline it replaces).
//!
//! A **vertex cut** assigns every *canonical undirected edge* of the input
//! graph to exactly one of `p` partitions ([`VertexCut::assignment`]);
//! vertices incident to edges in several partitions are *replicated*. The
//! materialized [`PartGraph`]s are self-contained local graphs — that is the
//! property that makes training communication-free.
//!
//! An **edge cut** assigns every *node* to one partition; cross-partition
//! edges are either dropped (the METIS row of Table 4) or served through
//! halo nodes + synchronization (the DistDGL/PipeGCN/BNS-GCN baselines,
//! whose traffic `simnet` models from the boundary statistics computed
//! here).

pub mod dar;
pub mod dbh;
pub mod edge_cut;
pub mod greedy;
pub mod hep;
pub mod metrics;
pub mod ne;
pub mod random;

use crate::graph::{Graph, GraphBuilder};
use crate::util::rng::Rng;
use rayon::prelude::*;
use std::collections::HashMap;

pub use dar::{dar_weights, Reweighting};
pub use edge_cut::{EdgeCut, LdgEdgeCut};
pub use metrics::{ManifestMetrics, PartitionMetrics};

/// A vertex-cut partitioning algorithm: maps each canonical edge to a part.
pub trait VertexCutAlgorithm {
    /// Short stable identifier (used in CLIs, tables, artifact names).
    fn name(&self) -> &'static str;
    /// Assignment of `g.edges()[k]` to a part in `0..p`.
    fn assign(&self, g: &Graph, p: usize, rng: &mut Rng) -> Vec<u32>;
}

/// One partition's local graph under a vertex cut.
#[derive(Clone, Debug)]
pub struct PartGraph {
    pub part_id: usize,
    /// Local node id -> global node id (sorted ascending).
    pub global_ids: Vec<u32>,
    /// The local topology: every edge assigned to this part, re-indexed to
    /// local ids. Symmetric CSR, exactly like the full [`Graph`].
    pub local: Graph,
}

impl PartGraph {
    /// Number of (replicated) nodes present in this partition.
    pub fn num_nodes(&self) -> usize {
        self.global_ids.len()
    }
    /// Number of canonical edges assigned to this partition.
    pub fn num_edges(&self) -> usize {
        self.local.num_edges()
    }
    /// Local id of a global node, if present (binary search).
    pub fn local_of(&self, global: u32) -> Option<u32> {
        self.global_ids.binary_search(&global).ok().map(|i| i as u32)
    }
}

/// A complete vertex-cut partitioning of a graph.
#[derive(Clone, Debug)]
pub struct VertexCut {
    pub num_parts: usize,
    /// Per canonical edge (index into `Graph::edges()`): owning part.
    pub assignment: Vec<u32>,
    pub parts: Vec<PartGraph>,
}

impl VertexCut {
    /// Run `algo` and materialize the per-partition local graphs.
    pub fn create(g: &Graph, p: usize, algo: &dyn VertexCutAlgorithm, rng: &mut Rng) -> VertexCut {
        let assignment = algo.assign(g, p, rng);
        Self::from_assignment(g, p, assignment)
    }

    /// Materialize from a precomputed edge assignment.
    ///
    /// Fast path: one counting-sort pass buckets the canonical edges by
    /// owning part (the scan preserves the global lexicographic order, so
    /// every bucket arrives pre-canonicalized, pre-sorted and
    /// duplicate-free), then the parts are materialized in parallel. The
    /// global→local remap is a binary search on the sorted id table — no
    /// per-part `HashMap` — and, because that map is monotone, the local
    /// edge list stays sorted and feeds [`Graph::from_sorted_edges`]
    /// directly, skipping `GraphBuilder`'s redundant re-sort/dedup.
    ///
    /// Output is byte-identical to [`VertexCut::from_assignment_reference`]
    /// for any rayon thread count (see the parity property test).
    pub fn from_assignment(g: &Graph, p: usize, assignment: Vec<u32>) -> VertexCut {
        assert_eq!(assignment.len(), g.num_edges(), "one part per canonical edge");
        assert!(assignment.iter().all(|&a| (a as usize) < p), "part id out of range");
        // Counting-sort bucketing: off[i]..off[i+1] is part i's edge range.
        let mut off = vec![0usize; p + 1];
        for &a in &assignment {
            off[a as usize + 1] += 1;
        }
        for i in 0..p {
            off[i + 1] += off[i];
        }
        let mut bucketed = vec![(0u32, 0u32); g.num_edges()];
        let mut cursor = off[..p].to_vec();
        for (k, &e) in g.edges().iter().enumerate() {
            let part = assignment[k] as usize;
            bucketed[cursor[part]] = e;
            cursor[part] += 1;
        }
        let parts: Vec<PartGraph> = (0..p)
            .into_par_iter()
            .map(|i| materialize_part(i, &bucketed[off[i]..off[i + 1]]))
            .collect();
        VertexCut { num_parts: p, assignment, parts }
    }

    /// The pre-optimization sequential materializer (per-part `HashMap`
    /// remap + `GraphBuilder` re-sort). Kept as the oracle the fast path is
    /// property-tested against, and as the "old" side of `bench_partition`.
    pub fn from_assignment_reference(g: &Graph, p: usize, assignment: Vec<u32>) -> VertexCut {
        assert_eq!(assignment.len(), g.num_edges(), "one part per canonical edge");
        assert!(assignment.iter().all(|&a| (a as usize) < p), "part id out of range");
        // Collect each part's global vertex set + edge list.
        let mut part_edges: Vec<Vec<(u32, u32)>> = vec![Vec::new(); p];
        for (k, &(u, v)) in g.edges().iter().enumerate() {
            part_edges[assignment[k] as usize].push((u, v));
        }
        let parts = part_edges
            .into_iter()
            .enumerate()
            .map(|(i, edges)| {
                let mut ids: Vec<u32> = edges.iter().flat_map(|&(u, v)| [u, v]).collect();
                ids.sort_unstable();
                ids.dedup();
                let index: HashMap<u32, u32> =
                    ids.iter().enumerate().map(|(l, &gid)| (gid, l as u32)).collect();
                let mut b = GraphBuilder::new(ids.len());
                for &(u, v) in &edges {
                    b.edge(index[&u], index[&v]);
                }
                PartGraph { part_id: i, global_ids: ids, local: b.edges(&[]).build_reference() }
            })
            .collect();
        VertexCut { num_parts: p, assignment, parts }
    }

    /// Per-node replication factor `RF(v) = Σ_i 1[v ∈ V[i]]` (0 for isolated
    /// nodes, which appear in no partition).
    pub fn node_replication(&self, g: &Graph) -> Vec<u32> {
        let mut rf = vec![0u32; g.num_nodes()];
        for part in &self.parts {
            for &gid in &part.global_ids {
                rf[gid as usize] += 1;
            }
        }
        rf
    }

    /// Check the vertex-cut invariants against the source graph.
    pub fn check_invariants(&self, g: &Graph) -> anyhow::Result<()> {
        use anyhow::ensure;
        ensure!(self.assignment.len() == g.num_edges());
        // Partition edge counts must sum to m (disjoint + covering, since
        // each edge is assigned exactly once by construction).
        let total: usize = self.parts.iter().map(|p| p.num_edges()).sum();
        ensure!(total == g.num_edges(), "edges lost or duplicated: {total} vs {}", g.num_edges());
        // Local degree sums must reconstruct global degrees.
        let mut deg = vec![0u64; g.num_nodes()];
        for part in &self.parts {
            part.local.check_invariants()?;
            for (l, &gid) in part.global_ids.iter().enumerate() {
                let d = part.local.degree(l as u32);
                ensure!(d > 0, "partition {} contains isolated replica of {gid}", part.part_id);
                deg[gid as usize] += d as u64;
            }
        }
        for v in 0..g.num_nodes() {
            ensure!(
                deg[v] == g.degree(v as u32) as u64,
                "degree of node {v} not preserved: {} vs {}",
                deg[v],
                g.degree(v as u32)
            );
        }
        // Edge sets must match exactly (re-projected to global ids).
        let mut all: Vec<(u32, u32)> = Vec::with_capacity(g.num_edges());
        for part in &self.parts {
            for &(lu, lv) in part.local.edges() {
                let gu = part.global_ids[lu as usize];
                let gv = part.global_ids[lv as usize];
                all.push(if gu < gv { (gu, gv) } else { (gv, gu) });
            }
        }
        all.sort_unstable();
        ensure!(all == g.edges(), "partition edges differ from graph edges");
        Ok(())
    }
}

/// Materialize one partition from its (sorted, canonical, unique) slice of
/// the bucketed edge list. Allocation-lean: the only allocations are the id
/// table, the local edge list and the CSR arrays themselves.
fn materialize_part(part_id: usize, edges: &[(u32, u32)]) -> PartGraph {
    let mut ids: Vec<u32> = Vec::with_capacity(edges.len() * 2);
    for &(u, v) in edges {
        ids.push(u);
        ids.push(v);
    }
    ids.sort_unstable();
    ids.dedup();
    // Monotone global→local remap by binary search: the bucketed slice is
    // lexicographically sorted with u < v, and a monotone map preserves
    // both, so the local list is directly CSR-ready.
    let local_edges: Vec<(u32, u32)> = edges
        .iter()
        .map(|&(u, v)| {
            let lu = ids.binary_search(&u).expect("endpoint in id table") as u32;
            let lv = ids.binary_search(&v).expect("endpoint in id table") as u32;
            (lu, lv)
        })
        .collect();
    let n_local = ids.len();
    PartGraph { part_id, global_ids: ids, local: Graph::from_sorted_edges(n_local, local_edges) }
}

/// Look up a vertex-cut algorithm by CLI name.
pub fn algorithm(name: &str) -> Option<Box<dyn VertexCutAlgorithm>> {
    match name {
        "random" => Some(Box::new(random::RandomVertexCut)),
        "dbh" => Some(Box::new(dbh::Dbh)),
        "greedy" => Some(Box::new(greedy::PowerGraphGreedy)),
        "greedy-seq" => Some(Box::new(greedy::SequentialGreedy)),
        "ne" => Some(Box::new(ne::NeighborExpansion::default())),
        "hep" => Some(Box::new(hep::Hep::default())),
        _ => None,
    }
}

/// All vertex-cut algorithm names (Table 4 order, plus the canonical-order
/// greedy variant the out-of-core pipeline can stream).
pub const ALGORITHMS: [&str; 6] = ["random", "ne", "dbh", "hep", "greedy", "greedy-seq"];

/// The algorithms the out-of-core streaming pipeline supports: those whose
/// assignment is computable in one pass over the canonical edge stream
/// with only O(V) state (a degree table plus per-vertex host bitsets).
/// `greedy` (shuffled stream) needs random access to the full edge list;
/// `ne`/`hep` need the full CSR.
pub const STREAMING_ALGORITHMS: [&str; 3] = ["random", "dbh", "greedy-seq"];

#[cfg(test)]
pub(crate) mod testutil {
    use super::*;
    use crate::graph::generators::{barabasi_albert, erdos_renyi};

    /// A small zoo of graphs for invariant tests.
    pub fn graph_zoo(seed: u64) -> Vec<Graph> {
        let rng = Rng::new(seed);
        let ring: Vec<(u32, u32)> = (0..40u32).map(|i| (i, (i + 1) % 40)).collect();
        vec![
            GraphBuilder::new(40).edges(&ring).build(),
            erdos_renyi(100, 300, &mut rng.fork(1)),
            barabasi_albert(200, 3, &mut rng.fork(2)),
            // Star: worst case for replication imbalance.
            GraphBuilder::new(65)
                .edges(&(1..65u32).map(|i| (0, i)).collect::<Vec<_>>())
                .build(),
            // With isolated nodes.
            GraphBuilder::new(20).edges(&[(0, 1), (2, 3), (4, 5)]).build(),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::testutil::graph_zoo;
    use super::*;

    /// Property test: every algorithm preserves the vertex-cut invariants on
    /// every zoo graph for several partition counts and seeds.
    #[test]
    fn all_algorithms_satisfy_invariants() {
        for (gi, g) in graph_zoo(42).iter().enumerate() {
            for &name in ALGORITHMS.iter() {
                let algo = algorithm(name).unwrap();
                for &p in &[1usize, 2, 3, 8] {
                    for seed in 0..3u64 {
                        let mut rng = Rng::new(seed * 1000 + gi as u64);
                        let vc = VertexCut::create(g, p, algo.as_ref(), &mut rng);
                        vc.check_invariants(g).unwrap_or_else(|e| {
                            panic!("{name} p={p} graph#{gi} seed={seed}: {e}")
                        });
                    }
                }
            }
        }
    }

    /// Full structural snapshot of a vertex cut: assignment, per-part id
    /// tables, canonical local edges and every adjacency row. Two cuts with
    /// equal snapshots are byte-identical for all observable purposes.
    fn snapshot(vc: &VertexCut) -> (Vec<u32>, Vec<(Vec<u32>, Vec<(u32, u32)>, Vec<u32>)>) {
        let parts = vc
            .parts
            .iter()
            .map(|part| {
                let rows: Vec<u32> = (0..part.local.num_nodes() as u32)
                    .flat_map(|v| part.local.neighbors(v).iter().copied())
                    .collect();
                (part.global_ids.clone(), part.local.edges().to_vec(), rows)
            })
            .collect();
        (vc.assignment.clone(), parts)
    }

    /// Property test (satellite): the counting-sort fast path produces
    /// byte-identical output to the retained sequential reference — same
    /// assignment, same global id tables, same local CSR, same edge order —
    /// across the whole graph zoo, every algorithm and several p.
    #[test]
    fn fast_materialization_matches_reference_on_zoo() {
        for (gi, g) in graph_zoo(7).iter().enumerate() {
            for &name in ALGORITHMS.iter() {
                let algo = algorithm(name).unwrap();
                for &p in &[1usize, 2, 3, 8] {
                    let mut rng = Rng::new(31 * gi as u64 + p as u64);
                    let assignment = algo.assign(g, p, &mut rng);
                    let fast = VertexCut::from_assignment(g, p, assignment.clone());
                    let slow = VertexCut::from_assignment_reference(g, p, assignment);
                    assert_eq!(
                        snapshot(&fast),
                        snapshot(&slow),
                        "{name} p={p} graph#{gi}: fast path diverged from reference"
                    );
                }
            }
        }
    }

    /// Materialization must be bit-identical regardless of the rayon pool
    /// size (the per-part map is index-ordered, so scheduling cannot leak
    /// into the output).
    #[test]
    fn materialization_identical_across_thread_counts() {
        let g = &graph_zoo(9)[2];
        let mut rng = Rng::new(404);
        let assignment = algorithm("greedy").unwrap().assign(g, 8, &mut rng);
        let baseline = snapshot(&VertexCut::from_assignment(g, 8, assignment.clone()));
        for threads in [1usize, 2, 8] {
            let pool = rayon::ThreadPoolBuilder::new().num_threads(threads).build().unwrap();
            let vc =
                pool.install(|| VertexCut::from_assignment(g, 8, assignment.clone()));
            assert_eq!(snapshot(&vc), baseline, "threads={threads}");
        }
    }

    #[test]
    fn replication_counts_match_metrics() {
        let g = &graph_zoo(1)[2];
        let mut rng = Rng::new(5);
        let vc = VertexCut::create(g, 4, &random::RandomVertexCut, &mut rng);
        let rf = vc.node_replication(g);
        let total: u32 = rf.iter().sum();
        let by_parts: usize = vc.parts.iter().map(|p| p.num_nodes()).sum();
        assert_eq!(total as usize, by_parts);
        // RF bounds: 1..=min(p, degree) for non-isolated nodes.
        for v in 0..g.num_nodes() as u32 {
            let d = g.degree(v);
            if d == 0 {
                assert_eq!(rf[v as usize], 0);
            } else {
                assert!(rf[v as usize] >= 1);
                assert!(rf[v as usize] <= d.min(4));
            }
        }
    }

    #[test]
    fn single_partition_is_identity() {
        let g = &graph_zoo(2)[1];
        let mut rng = Rng::new(0);
        let vc = VertexCut::create(g, 1, &random::RandomVertexCut, &mut rng);
        assert_eq!(vc.parts.len(), 1);
        assert_eq!(vc.parts[0].num_edges(), g.num_edges());
        // Every non-isolated node appears exactly once.
        let rf = vc.node_replication(g);
        for v in 0..g.num_nodes() as u32 {
            assert_eq!(rf[v as usize], u32::from(g.degree(v) > 0));
        }
    }

    #[test]
    fn local_of_lookup() {
        let g = &graph_zoo(3)[0];
        let mut rng = Rng::new(1);
        let vc = VertexCut::create(g, 2, &random::RandomVertexCut, &mut rng);
        for part in &vc.parts {
            for (l, &gid) in part.global_ids.iter().enumerate() {
                assert_eq!(part.local_of(gid), Some(l as u32));
            }
            assert_eq!(part.local_of(10_000), None);
        }
    }

    #[test]
    fn algorithm_lookup() {
        for &name in ALGORITHMS.iter() {
            assert!(algorithm(name).is_some(), "{name}");
            assert_eq!(algorithm(name).unwrap().name(), name);
        }
        assert!(algorithm("metis").is_none());
    }
}
