//! Cross-module property tests: randomized graphs × partitioners × p,
//! checking the system-level invariants end to end (no artifacts needed).
//!
//! This is the crate's proptest-style suite: a seeded generator produces
//! arbitrary graphs (several families, random sizes), and every case must
//! uphold the invariants the distributed-training semantics rely on.

use cofree_gnn::graph::generators::{
    barabasi_albert, chung_lu, erdos_renyi, planted_communities, power_law_degrees,
};
use cofree_gnn::graph::{io, Graph, GraphBuilder};
use cofree_gnn::partition::{
    algorithm, dar_weights, LdgEdgeCut, PartitionMetrics, Reweighting, VertexCut, ALGORITHMS,
};
use cofree_gnn::train::bucket::{bucket_shapes, full_graph_bucket};
use cofree_gnn::util::rng::Rng;

/// Draw a random graph from a random family.
fn arbitrary_graph(rng: &mut Rng) -> Graph {
    let family = rng.below(5);
    let n = 50 + rng.below(400);
    match family {
        0 => erdos_renyi(n, n * (1 + rng.below(6)), &mut rng.fork(1)),
        1 => barabasi_albert(n, 1 + rng.below(4), &mut rng.fork(2)),
        2 => {
            let w = power_law_degrees(n, 2.1 + rng.f64(), 2, (n / 4).max(8) as u32, &mut rng.fork(3));
            chung_lu(&w, &mut rng.fork(4))
        }
        3 => planted_communities(n, 2 + rng.below(6), 6.0, 1.5, &mut rng.fork(5)).0,
        _ => {
            // Pathological: star + ring + isolated nodes.
            let mut b = GraphBuilder::new(n);
            for i in 1..(n as u32 / 2) {
                b.edge(0, i);
            }
            for i in (n as u32 / 2)..(n as u32 - 5) {
                b.edge(i, i + 1);
            }
            b.edges(&[]).build()
        }
    }
}

const CASES: u64 = 25;

#[test]
fn prop_vertex_cut_invariants_hold_for_all_algorithms() {
    for case in 0..CASES {
        let mut rng = Rng::new(0xC0FFEE ^ case);
        let g = arbitrary_graph(&mut rng);
        let p = 1 + rng.below(12);
        for name in ALGORITHMS {
            let vc = VertexCut::create(&g, p, algorithm(name).unwrap().as_ref(), &mut rng.fork(7));
            vc.check_invariants(&g)
                .unwrap_or_else(|e| panic!("case {case} {name} p={p}: {e}"));
        }
    }
}

#[test]
fn prop_dar_weights_always_sum_to_one() {
    for case in 0..CASES {
        let mut rng = Rng::new(0xDA2 ^ case);
        let g = arbitrary_graph(&mut rng);
        let p = 2 + rng.below(10);
        let name = ALGORITHMS[rng.below(ALGORITHMS.len())];
        let vc = VertexCut::create(&g, p, algorithm(name).unwrap().as_ref(), &mut rng.fork(1));
        for scheme in [Reweighting::Dar, Reweighting::VanillaInv] {
            let w = dar_weights(&g, &vc, scheme);
            let mut per_node = vec![0f64; g.num_nodes()];
            for (i, part) in vc.parts.iter().enumerate() {
                for (l, &gid) in part.global_ids.iter().enumerate() {
                    per_node[gid as usize] += w[i][l] as f64;
                }
            }
            for v in 0..g.num_nodes() {
                if g.degree(v as u32) > 0 {
                    assert!(
                        (per_node[v] - 1.0).abs() < 1e-4,
                        "case {case} {name} {scheme:?} node {v}: {}",
                        per_node[v]
                    );
                }
            }
        }
    }
}

#[test]
fn prop_replication_factor_bounds() {
    // 1 <= RF(G) <= min(p, max_degree) for any vertex cut; per-node
    // RF(v) <= min(p, deg(v)).
    for case in 0..CASES {
        let mut rng = Rng::new(0x2F ^ case);
        let g = arbitrary_graph(&mut rng);
        if g.num_edges() == 0 {
            continue;
        }
        let p = 1 + rng.below(12);
        let name = ALGORITHMS[rng.below(ALGORITHMS.len())];
        let vc = VertexCut::create(&g, p, algorithm(name).unwrap().as_ref(), &mut rng.fork(1));
        let m = PartitionMetrics::vertex_cut(&g, &vc);
        assert!(m.replication_factor >= 1.0 - 1e-9, "case {case}");
        assert!(m.replication_factor <= p as f64 + 1e-9, "case {case}");
        let rf = vc.node_replication(&g);
        for v in 0..g.num_nodes() as u32 {
            assert!(rf[v as usize] <= g.degree(v).min(p as u32), "case {case} node {v}");
        }
    }
}

#[test]
fn prop_edge_cut_invariants_and_thm41() {
    use cofree_gnn::partition::edge_cut::vertex_cut_from_edge_cut;
    for case in 0..CASES {
        let mut rng = Rng::new(0xEC ^ case);
        let g = arbitrary_graph(&mut rng);
        let p = 2 + rng.below(8);
        let ec = LdgEdgeCut::default().partition(&g, p, &mut rng.fork(1));
        ec.check_invariants(&g).unwrap_or_else(|e| panic!("case {case}: {e}"));
        // Theorem 4.1 whenever the cut is non-trivial.
        let (halos, vc) = vertex_cut_from_edge_cut(&g, &ec);
        vc.check_invariants(&g).unwrap();
        if halos > 0 {
            let dup: usize =
                vc.node_replication(&g).iter().map(|&r| (r.max(1) - 1) as usize).sum();
            assert!(dup < halos, "case {case}: Thm 4.1 violated ({dup} >= {halos})");
        }
    }
}

#[test]
fn prop_bucket_ladder_always_covers_ne_partitions() {
    for case in 0..CASES {
        let mut rng = Rng::new(0xB0C ^ case);
        let g = arbitrary_graph(&mut rng);
        if g.num_edges() < 8 {
            continue;
        }
        let p = 1 + rng.below(10);
        let (n, m) = (g.num_nodes(), g.num_edges());
        let mut ladder: Vec<(usize, usize)> = (1..=p).map(|q| bucket_shapes(n, m, q)).collect();
        ladder.push(full_graph_bucket(n, m));
        let vc = VertexCut::create(&g, p, algorithm("ne").unwrap().as_ref(), &mut rng.fork(1));
        for part in &vc.parts {
            assert!(
                ladder
                    .iter()
                    .any(|&(np, ep)| part.num_nodes() <= np && 2 * part.num_edges() <= ep),
                "case {case} p={p}: partition ({} n, {} e) unfittable",
                part.num_nodes(),
                part.num_edges()
            );
        }
    }
}

#[test]
fn prop_snapshot_roundtrip_any_graph() {
    for case in 0..8u64 {
        let mut rng = Rng::new(0x10 ^ case);
        let g = arbitrary_graph(&mut rng);
        let path = std::env::temp_dir().join(format!(
            "cofree_prop_{}_{case}.bin",
            std::process::id()
        ));
        io::write_snapshot(&g, None, &path).unwrap();
        let (g2, nd) = io::read_snapshot(&path).unwrap();
        assert!(nd.is_none());
        assert_eq!(g.num_nodes(), g2.num_nodes());
        assert_eq!(g.edges(), g2.edges());
        std::fs::remove_file(&path).unwrap();
    }
}

#[test]
fn prop_partition_determinism() {
    // Same seed => identical assignment, different seed => (almost surely)
    // different assignment for randomized algorithms.
    for case in 0..10u64 {
        let mut rng = Rng::new(0xDE ^ case);
        let g = arbitrary_graph(&mut rng);
        if g.num_edges() < 20 {
            continue;
        }
        for name in ALGORITHMS {
            let algo = algorithm(name).unwrap();
            let a = algo.assign(&g, 4, &mut Rng::new(1234));
            let b = algo.assign(&g, 4, &mut Rng::new(1234));
            assert_eq!(a, b, "case {case} {name} not deterministic");
        }
    }
}
