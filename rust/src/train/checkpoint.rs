//! Model checkpointing: serialize/restore parameters + optimizer state.
//!
//! `cofree train --save-model m.bin` writes a [`TrainCheckpoint`] after
//! training; `--load-model m.bin` restores it and continues, and the
//! continued trajectory is **bit-identical** to an uninterrupted run of the
//! same total length (the engine replays the epoch-level RNG draws for the
//! already-completed epochs, so DropEdge picks and Rotate selections line
//! up — see `TrainEngine::train_resumable`).
//!
//! The file format reuses the shard store's header/versioning helpers
//! ([`crate::util::binio`]): magic + u32 version, then little-endian
//! length-prefixed tensors. All f32 payloads round-trip bit-exactly.

use crate::runtime::{ModelConfig, ParamSet};
use crate::train::model::ModelKind;
use crate::train::optimizer::OptimizerState;
use crate::util::binio;
use anyhow::{bail, ensure, Context, Result};
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

pub const CHECKPOINT_MAGIC: &[u8; 8] = b"COFREECK";
/// Version 2 added the model-kind tag to the header (the `GnnModel`
/// refactor): a checkpoint records WHICH architecture its parameters
/// belong to, not just the dims, so loading a GCN checkpoint into a Sage
/// run fails loudly instead of misindexing tensors.
pub const CHECKPOINT_VERSION: u32 = 2;

/// A resumable training state: how many epochs are done, the parameters,
/// and the optimizer's internal state.
#[derive(Clone, Debug)]
pub struct TrainCheckpoint {
    /// Number of epochs already completed when this state was taken.
    pub epochs_done: usize,
    /// Model the parameters belong to (validated on resume).
    pub model: ModelConfig,
    pub params: ParamSet,
    pub opt: OptimizerState,
}

fn write_param_list(w: &mut impl Write, data: &[Vec<f32>]) -> Result<()> {
    binio::write_u32(w, data.len() as u32)?;
    for t in data {
        binio::write_f32s(w, t)?;
    }
    Ok(())
}

fn read_param_list(r: &mut impl Read) -> Result<Vec<Vec<f32>>> {
    let k = binio::read_u32(r)? as usize;
    ensure!(k <= 4096, "corrupt checkpoint: {k} tensors");
    (0..k).map(|_| binio::read_f32s(r)).collect()
}

impl TrainCheckpoint {
    /// Serialize to `path`. Returns the number of bytes written.
    pub fn save(&self, path: &Path) -> Result<u64> {
        let f = std::fs::File::create(path).with_context(|| format!("create {path:?}"))?;
        let mut w = BufWriter::new(f);
        binio::write_magic(&mut w, CHECKPOINT_MAGIC)?;
        binio::write_version(&mut w, CHECKPOINT_VERSION)?;
        binio::write_u64(&mut w, self.epochs_done as u64)?;
        binio::write_u8(&mut w, self.model.kind.code())?;
        for d in [self.model.layers, self.model.feat_dim, self.model.hidden, self.model.classes] {
            binio::write_u32(&mut w, d as u32)?;
        }
        // Parameter dims then data (dims are re-derivable from the model but
        // stored anyway so a reader can validate without model code).
        binio::write_u32(&mut w, self.params.dims.len() as u32)?;
        for dims in &self.params.dims {
            binio::write_u32(&mut w, dims.len() as u32)?;
            for &d in dims {
                binio::write_u64(&mut w, d as u64)?;
            }
        }
        write_param_list(&mut w, &self.params.data)?;
        match &self.opt {
            OptimizerState::Sgd => binio::write_u8(&mut w, 0)?,
            OptimizerState::Adam { t, m, v } => {
                binio::write_u8(&mut w, 1)?;
                binio::write_u64(&mut w, *t as u64)?;
                write_param_list(&mut w, m)?;
                write_param_list(&mut w, v)?;
            }
        }
        w.flush()?;
        let bytes = std::fs::metadata(path)?.len();
        Ok(bytes)
    }

    /// Deserialize from `path`, validating magic, version and shape
    /// consistency.
    pub fn load(path: &Path) -> Result<TrainCheckpoint> {
        let f = std::fs::File::open(path).with_context(|| format!("open {path:?}"))?;
        let mut r = BufReader::new(f);
        binio::expect_magic(&mut r, CHECKPOINT_MAGIC, "cofree model checkpoint")
            .with_context(|| format!("reading {path:?}"))?;
        binio::expect_version(&mut r, CHECKPOINT_VERSION, "model checkpoint")?;
        let epochs_done = binio::read_u64(&mut r)? as usize;
        let kind = ModelKind::from_code(binio::read_u8(&mut r)?)
            .context("reading checkpoint model kind")?;
        let model = ModelConfig {
            kind,
            layers: binio::read_u32(&mut r)? as usize,
            feat_dim: binio::read_u32(&mut r)? as usize,
            hidden: binio::read_u32(&mut r)? as usize,
            classes: binio::read_u32(&mut r)? as usize,
        };
        let k = binio::read_u32(&mut r)? as usize;
        ensure!(k <= 4096, "corrupt checkpoint: {k} parameter tensors");
        let mut dims = Vec::with_capacity(k);
        for _ in 0..k {
            let rank = binio::read_u32(&mut r)? as usize;
            ensure!(rank <= 8, "corrupt checkpoint: rank {rank}");
            let mut shape = Vec::with_capacity(rank);
            for _ in 0..rank {
                shape.push(binio::read_u64(&mut r)? as usize);
            }
            dims.push(shape);
        }
        let data = read_param_list(&mut r)?;
        ensure!(
            dims.len() == data.len(),
            "checkpoint dims/data arity mismatch: {} vs {}",
            dims.len(),
            data.len()
        );
        for (i, (shape, d)) in dims.iter().zip(&data).enumerate() {
            let want: usize = shape.iter().product();
            ensure!(d.len() == want, "checkpoint tensor {i}: {} elements, dims say {want}", d.len());
        }
        ensure!(
            dims == model.param_shapes(),
            "checkpoint parameter shapes do not match its model config"
        );
        let opt = match binio::read_u8(&mut r)? {
            0 => OptimizerState::Sgd,
            1 => {
                let t = binio::read_u64(&mut r)? as i32;
                let m = read_param_list(&mut r)?;
                let v = read_param_list(&mut r)?;
                ensure!(
                    m.len() == data.len() && v.len() == data.len(),
                    "adam moment arity does not match parameters"
                );
                OptimizerState::Adam { t, m, v }
            }
            other => bail!("unknown optimizer kind tag {other} in checkpoint"),
        };
        Ok(TrainCheckpoint { epochs_done, model, params: ParamSet { dims, data }, opt })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("cofree_ckpt_{name}_{}", std::process::id()))
    }

    fn sample_kind(kind: ModelKind) -> TrainCheckpoint {
        let model = ModelConfig { kind, layers: 2, feat_dim: 6, hidden: 8, classes: 4 };
        let params = ParamSet::init_glorot(&model, &mut Rng::new(3));
        let m = params.data.iter().map(|d| d.iter().map(|x| x * 0.5).collect()).collect();
        let v = params.data.iter().map(|d| d.iter().map(|x| x * x).collect()).collect();
        TrainCheckpoint { epochs_done: 7, model, params, opt: OptimizerState::Adam { t: 7, m, v } }
    }

    fn sample() -> TrainCheckpoint {
        sample_kind(ModelKind::Sage)
    }

    /// Round-trips (Adam moments included) for every model kind: the
    /// header records the kind and it survives save → load bit-exactly.
    #[test]
    fn roundtrip_is_bit_exact_for_every_kind() {
        for kind in ModelKind::ALL {
            let ck = sample_kind(kind);
            let p = tmp(kind.name());
            let bytes = ck.save(&p).unwrap();
            assert!(bytes > 0);
            let got = TrainCheckpoint::load(&p).unwrap();
            assert_eq!(got.epochs_done, ck.epochs_done);
            assert_eq!(got.model, ck.model);
            assert_eq!(got.model.kind, kind);
            assert_eq!(got.params.dims, ck.params.dims);
            assert_eq!(got.params.data, ck.params.data);
            assert_eq!(got.opt, ck.opt);
            std::fs::remove_file(&p).unwrap();
        }
    }

    /// The kinds' parameter layouts really differ (so a kind mismatch can
    /// never alias silently), and the engine-side mismatch check has both
    /// kinds in its message (`train_resumable` ensures `ck.model ==
    /// run.model`; see `tests/train_native.rs` for the end-to-end case).
    #[test]
    fn kind_mismatch_cannot_alias() {
        let sage = sample_kind(ModelKind::Sage);
        let gcn = sample_kind(ModelKind::Gcn);
        let gin = sample_kind(ModelKind::Gin);
        assert_ne!(sage.params.dims, gcn.params.dims);
        assert_ne!(gcn.params.dims, gin.params.dims);
        assert_ne!(sage.model, gcn.model);
    }

    #[test]
    fn sgd_state_roundtrips() {
        let mut ck = sample();
        ck.opt = OptimizerState::Sgd;
        let p = tmp("sgd");
        ck.save(&p).unwrap();
        assert_eq!(TrainCheckpoint::load(&p).unwrap().opt, OptimizerState::Sgd);
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn wrong_magic_reports_found_vs_expected() {
        let p = tmp("bad");
        std::fs::write(&p, b"COFREEG1junkjunkjunk").unwrap();
        let err = TrainCheckpoint::load(&p).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("COFREECK") && msg.contains("COFREEG1"), "{msg}");
        std::fs::remove_file(&p).unwrap();
    }
}
