//! End-to-end multi-process training (default features).
//!
//! The distributed determinism contract, proven over real process
//! boundaries: `cofree`'s shard store + coordinator/worker protocol must
//! reproduce the in-process engine's trajectory **bit-for-bit** — losses,
//! accuracies, and final parameters — for the same dataset, cut, seed and
//! config. Worker processes are the actual `cofree` binary
//! (`CARGO_BIN_EXE_cofree`), spawned over TCP (and a Unix socket variant),
//! so these tests exercise shard I/O, the wire protocol, the handshake,
//! and the rank-ordered gradient fold, not a mock.

use cofree_gnn::dist::{self, DistStats, ProcOptions, Transport, EXPECTED_F32_BYTES_PER_PARAM};
use cofree_gnn::graph::{datasets, Dataset};
use cofree_gnn::partition::{algorithm, dar_weights, Reweighting, VertexCut};
use cofree_gnn::runtime::ParamSet;
use cofree_gnn::train::engine::{TrainConfig, TrainEngine};
use cofree_gnn::train::model::ModelKind;
use cofree_gnn::train::metrics::History;
use cofree_gnn::util::rng::Rng;
use std::path::PathBuf;

fn worker_bin() -> PathBuf {
    PathBuf::from(env!("CARGO_BIN_EXE_cofree"))
}

fn ds_small() -> Dataset {
    // ~400 nodes, ~2k edges: whole fleets run in seconds.
    datasets::build("yelp-sim", 0.04, 7).unwrap()
}

fn cut(ds: &Dataset, p: usize, seed: u64) -> VertexCut {
    let mut rng = Rng::new(seed);
    VertexCut::create(&ds.graph, p, algorithm("dbh").unwrap().as_ref(), &mut rng)
}

fn cfg_for(epochs: usize, seed: u64, dropedge: Option<(usize, f64)>) -> TrainConfig {
    TrainConfig { epochs, eval_every: 5, dropedge, seed, ..Default::default() }
}

/// The in-process reference trajectory.
fn run_inproc_model(
    kind: ModelKind,
    p: usize,
    seed: u64,
    dropedge: Option<(usize, f64)>,
    epochs: usize,
) -> (History, ParamSet) {
    let ds = ds_small();
    let vc = cut(&ds, p, seed);
    let mut engine = TrainEngine::native_model(kind);
    let eval = engine.prepare_eval(&ds).unwrap();
    let mut run = engine
        .prepare_partitions(&ds, &vc, Reweighting::Dar, dropedge, seed)
        .unwrap();
    let cfg = cfg_for(epochs, seed, dropedge);
    let (h, params, _) = engine.train(&mut run, Some(&eval), &cfg).unwrap();
    (h, params)
}

fn run_inproc(
    p: usize,
    seed: u64,
    dropedge: Option<(usize, f64)>,
    epochs: usize,
) -> (History, ParamSet) {
    run_inproc_model(ModelKind::Sage, p, seed, dropedge, epochs)
}

/// The same trajectory over real worker processes.
fn run_proc_model(
    kind: ModelKind,
    p: usize,
    seed: u64,
    dropedge: Option<(usize, f64)>,
    epochs: usize,
    transport: Transport,
    tag: &str,
) -> (History, ParamSet, DistStats) {
    let ds = ds_small();
    let vc = cut(&ds, p, seed);
    let weights = dar_weights(&ds.graph, &vc, Reweighting::Dar);
    let dir = std::env::temp_dir().join(format!(
        "cofree_dist_test_{tag}_{}_{p}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    dist::write_shards(&ds, &vc, &weights, seed, &dir).unwrap();
    let opts = ProcOptions { transport, model: kind, ..ProcOptions::new(worker_bin()) };
    let cfg = cfg_for(epochs, seed, dropedge);
    let (h, ck, stats) = dist::train_over_shards(&ds, &dir, &cfg, &opts, None).unwrap();
    std::fs::remove_dir_all(&dir).unwrap();
    (h, ck.params, stats)
}

fn run_proc(
    p: usize,
    seed: u64,
    dropedge: Option<(usize, f64)>,
    epochs: usize,
    transport: Transport,
    tag: &str,
) -> (History, ParamSet, DistStats) {
    run_proc_model(ModelKind::Sage, p, seed, dropedge, epochs, transport, tag)
}

fn assert_trajectories_identical(a: &History, b: &History) {
    assert_eq!(a.epochs.len(), b.epochs.len());
    for (x, y) in a.epochs.iter().zip(&b.epochs) {
        assert_eq!(x.epoch, y.epoch);
        assert_eq!(
            x.train_loss.to_bits(),
            y.train_loss.to_bits(),
            "epoch {} loss: {} vs {}",
            x.epoch,
            x.train_loss,
            y.train_loss
        );
        assert_eq!(x.train_acc.to_bits(), y.train_acc.to_bits(), "epoch {} acc", x.epoch);
        // val/test are NaN on non-eval epochs on both sides identically.
        assert_eq!(x.val_acc.to_bits(), y.val_acc.to_bits(), "epoch {} val", x.epoch);
        assert_eq!(x.test_acc.to_bits(), y.test_acc.to_bits(), "epoch {} test", x.epoch);
    }
}

/// The 2-process smoke test (CI satellite): trajectory parity with DropEdge
/// in play, so shard bytes, mask-bank RNG forking, pick broadcasting and
/// the gradient fold all have to line up.
#[test]
fn two_process_training_matches_inproc_bitwise() {
    let (p, seed, epochs) = (2usize, 11u64, 6usize);
    let dropedge = Some((3usize, 0.4f64));
    let (h_in, params_in) = run_inproc(p, seed, dropedge, epochs);
    let (h_proc, params_proc, stats) = run_proc(p, seed, dropedge, epochs, Transport::Tcp, "two");
    assert_trajectories_identical(&h_in, &h_proc);
    assert_eq!(params_in.data, params_proc.data, "final parameters diverged");
    // Wire accounting: roughly 4 bytes of θ down + 4 bytes of ∇ up per
    // parameter per worker per epoch, plus small framing overhead.
    assert_eq!(stats.epochs_run, epochs);
    assert_eq!(stats.num_workers, p);
    assert!(stats.bytes_sent > 0 && stats.bytes_recv > 0);
    let ideal = (EXPECTED_F32_BYTES_PER_PARAM * p * params_in.num_elements()) as f64;
    let per_epoch = stats.bytes_per_epoch();
    assert!(per_epoch >= ideal, "per-epoch bytes {per_epoch} below the {ideal} floor?");
    assert!(
        per_epoch < ideal * 1.25,
        "framing overhead too large: {per_epoch} vs ideal {ideal}"
    );
}

/// The acceptance-criteria shape: 4 workers, multi-epoch, bit-identical
/// trajectory (no DropEdge — exercises the pick=None path).
#[test]
fn four_process_training_matches_inproc_bitwise() {
    let (p, seed, epochs) = (4usize, 21u64, 5usize);
    let (h_in, params_in) = run_inproc(p, seed, None, epochs);
    let (h_proc, params_proc, stats) = run_proc(p, seed, None, epochs, Transport::Tcp, "four");
    assert_trajectories_identical(&h_in, &h_proc);
    assert_eq!(params_in.data, params_proc.data);
    assert_eq!(stats.num_workers, 4);
}

/// The overlapped collect path (broadcast to all, then readiness-poll the
/// results as they arrive) must keep the trajectory bit-identical even
/// with an odd worker count, skewed shard sizes (dbh on a power-law graph)
/// and DropEdge picks in play — results land by rank however the sockets
/// drain, and the fold stays in rank order.
#[test]
fn overlapped_collect_with_uneven_workers_matches_inproc_bitwise() {
    let (p, seed, epochs) = (3usize, 41u64, 5usize);
    let dropedge = Some((2usize, 0.3f64));
    let (h_in, params_in) = run_inproc(p, seed, dropedge, epochs);
    let (h_proc, params_proc, stats) =
        run_proc(p, seed, dropedge, epochs, Transport::Tcp, "uneven");
    assert_trajectories_identical(&h_in, &h_proc);
    assert_eq!(params_in.data, params_proc.data, "final parameters diverged");
    assert_eq!(stats.num_workers, 3);
    assert_eq!(stats.epochs_run, epochs);
}

/// Unix-domain sockets carry the same bits as TCP.
#[cfg(unix)]
#[test]
fn unix_socket_transport_matches_inproc() {
    let (p, seed, epochs) = (2usize, 31u64, 3usize);
    let (_, params_in) = run_inproc(p, seed, None, epochs);
    let (_, params_proc, _) = run_proc(p, seed, None, epochs, Transport::Unix, "unix");
    assert_eq!(params_in.data, params_proc.data);
}

/// The CLI surface end-to-end: `cofree shard` + `cofree train --transport
/// proc --workers 4 --shard-dir …` completes multi-epoch training against
/// a pre-written store.
#[test]
fn cli_shard_then_train_proc() {
    use cofree_gnn::coordinator::cli;
    let dir = std::env::temp_dir().join(format!("cofree_cli_proc_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let argv = |s: &[&str]| s.iter().map(|x| x.to_string()).collect::<Vec<_>>();
    let code = cli::main(argv(&[
        "shard",
        "--dataset",
        "yelp-sim",
        "--scale",
        "0.04",
        "--partitions",
        "4",
        "--out",
        dir.to_str().unwrap(),
    ]))
    .unwrap();
    assert_eq!(code, 0);
    let bin = worker_bin();
    let code = cli::main(argv(&[
        "train",
        "--dataset",
        "yelp-sim",
        "--scale",
        "0.04",
        "--partitions",
        "4",
        "--epochs",
        "4",
        "--transport",
        "proc",
        "--workers",
        "4",
        "--shard-dir",
        dir.to_str().unwrap(),
        "--worker-bin",
        bin.to_str().unwrap(),
    ]))
    .unwrap();
    assert_eq!(code, 0);
    std::fs::remove_dir_all(&dir).unwrap();
}

/// Acceptance criterion of the `GnnModel` refactor: both NEW architectures
/// train end-to-end over the proc transport with trajectories bit-identical
/// to inproc — one shard store serves every model (shards carry dims only;
/// the kind travels in the wire Config frame), and DropEdge stays in play
/// for GCN so the mask-pick plumbing is exercised on a non-Sage model.
#[test]
fn gcn_proc_training_matches_inproc_bitwise() {
    let (p, seed, epochs) = (2usize, 61u64, 4usize);
    let dropedge = Some((2usize, 0.3f64));
    let (h_in, params_in) = run_inproc_model(ModelKind::Gcn, p, seed, dropedge, epochs);
    let (h_proc, params_proc, stats) =
        run_proc_model(ModelKind::Gcn, p, seed, dropedge, epochs, Transport::Tcp, "gcn");
    assert_trajectories_identical(&h_in, &h_proc);
    assert_eq!(params_in.data, params_proc.data, "gcn final parameters diverged");
    assert_eq!(stats.num_workers, p);
    // The wire accounting scales with the GCN parameter count, not Sage's.
    assert_eq!(stats.num_params, params_in.num_elements());
}

/// Observability acceptance: a 2-worker proc run with BOTH telemetry
/// surfaces active (`metrics_out` ledger + span tracing, the library side
/// of `--metrics-out`/`--trace-out`) keeps the trajectory bit-identical
/// to the uninstrumented inproc reference, leaves one valid JSONL epoch
/// record per epoch plus a summary whose `dist.per_rank` covers every
/// rank, and exports a Chrome trace with spans from the coordinator and
/// every worker pid.
#[test]
fn telemetry_active_proc_run_is_bit_identical_and_artifacts_validate() {
    use cofree_gnn::util::json;
    let (p, seed, epochs) = (2usize, 11u64, 6usize);
    let dropedge = Some((3usize, 0.4f64));
    // Uninstrumented reference, trained before tracing is switched on.
    let (h_in, params_in) = run_inproc(p, seed, dropedge, epochs);

    let ds = ds_small();
    let vc = cut(&ds, p, seed);
    let weights = dar_weights(&ds.graph, &vc, Reweighting::Dar);
    let dir = std::env::temp_dir().join(format!("cofree_dist_obs_{}_{p}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dist::write_shards(&ds, &vc, &weights, seed, &dir).unwrap();
    let ledger = dir.join("metrics.jsonl");
    let trace = dir.join("trace.json");

    cofree_gnn::obs::trace::enable();
    let opts = ProcOptions { transport: Transport::Tcp, ..ProcOptions::new(worker_bin()) };
    let mut cfg = cfg_for(epochs, seed, dropedge);
    cfg.metrics_out = Some(ledger.clone());
    let (h_proc, ck, stats) = dist::train_over_shards(&ds, &dir, &cfg, &opts, None).unwrap();
    cofree_gnn::obs::trace::write_chrome(&trace).unwrap();
    cofree_gnn::obs::trace::disable();
    cofree_gnn::obs::append_summary(
        &ledger,
        &h_proc,
        &[("optim", stats.optim_seconds)],
        Some(&stats),
    )
    .unwrap();

    // Telemetry reads clocks and atomics only: same bits as the plain run.
    assert_trajectories_identical(&h_in, &h_proc);
    assert_eq!(params_in.data, ck.params.data, "telemetry perturbed the trajectory");

    // Ledger: one epoch record per epoch, then the summary.
    let text = std::fs::read_to_string(&ledger).unwrap();
    let lines: Vec<&str> = text.lines().collect();
    assert_eq!(lines.len(), epochs + 1, "ledger:\n{text}");
    for (i, line) in lines.iter().take(epochs).enumerate() {
        let r = json::parse(line.as_bytes()).expect("epoch record parses");
        assert_eq!(r.get("record").and_then(|v| v.as_str()), Some("epoch"));
        assert_eq!(r.get("epoch").and_then(|v| v.as_u64()), Some(i as u64));
        assert!(r.get("epoch_s").and_then(|v| v.as_f64()).unwrap() >= 0.0);
    }
    let s = json::parse(lines[epochs].as_bytes()).expect("summary record parses");
    assert_eq!(s.get("record").and_then(|v| v.as_str()), Some("summary"));
    assert_eq!(s.get("epochs").and_then(|v| v.as_u64()), Some(epochs as u64));
    let per_rank = s
        .get("dist")
        .and_then(|d| d.get("per_rank"))
        .and_then(|v| v.as_arr())
        .expect("summary carries dist.per_rank");
    assert_eq!(per_rank.len(), p, "one phase breakdown per rank");
    for (rank, r) in per_rank.iter().enumerate() {
        assert_eq!(r.get("rank").and_then(|v| v.as_u64()), Some(rank as u64));
        assert_eq!(
            r.get("steps").and_then(|v| v.as_u64()),
            Some(epochs as u64),
            "rank {rank} steps"
        );
        assert!(r.get("compute_s").and_then(|v| v.as_f64()).unwrap() >= 0.0);
        assert!(r.get("forward_s").and_then(|v| v.as_f64()).is_some());
        assert!(r.get("backward_s").and_then(|v| v.as_f64()).is_some());
    }

    // Trace: coordinator (pid 0) plus every worker rank (pid r+1).
    let tdoc = json::parse(std::fs::read_to_string(&trace).unwrap().as_bytes())
        .expect("trace parses as trace-event JSON");
    let events = tdoc.as_arr().expect("trace is an array");
    let mut pids: Vec<u64> = events
        .iter()
        .filter(|e| e.get("ph").and_then(|x| x.as_str()) == Some("X"))
        .filter_map(|e| e.get("pid").and_then(|v| v.as_u64()))
        .collect();
    pids.sort_unstable();
    pids.dedup();
    for want in 0..=(p as u64) {
        assert!(pids.contains(&want), "trace is missing spans for pid {want} (have {pids:?})");
    }
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn gin_proc_training_matches_inproc_bitwise() {
    let (p, seed, epochs) = (3usize, 71u64, 4usize);
    let (h_in, params_in) = run_inproc_model(ModelKind::Gin, p, seed, None, epochs);
    let (h_proc, params_proc, stats) =
        run_proc_model(ModelKind::Gin, p, seed, None, epochs, Transport::Tcp, "gin");
    assert_trajectories_identical(&h_in, &h_proc);
    assert_eq!(params_in.data, params_proc.data, "gin final parameters diverged");
    assert_eq!(stats.num_workers, p);
}

/// The v6 wire-parity invariant, end to end: a fleet running the bf16
/// storage tier with the bf16 wire codec (`--precision bf16
/// --wire-compress bf16`) reproduces the single-process bf16 trajectory
/// bit-for-bit. Workers stage parameters through bf16 at the top of every
/// step and round every gradient to bf16 before it leaves, so the 2-byte
/// codec is lossless for this tier — compression without a trajectory
/// change. Runs with wire digests on, so the CRC trailer rides the
/// compressed payload too.
#[test]
fn bf16_fleet_with_bf16_codec_matches_inproc_bf16_bitwise() {
    use cofree_gnn::dist::proto::WireCodec;
    use cofree_gnn::train::Precision;
    let (p, seed, epochs) = (2usize, 31u64, 5usize);
    let dropedge = Some((3usize, 0.4f64));

    let ds = ds_small();
    let vc = cut(&ds, p, seed);
    let mut engine = TrainEngine::native_model_prec(ModelKind::Sage, Precision::Bf16);
    let eval = engine.prepare_eval(&ds).unwrap();
    let mut run = engine
        .prepare_partitions(&ds, &vc, Reweighting::Dar, dropedge, seed)
        .unwrap();
    let cfg = cfg_for(epochs, seed, dropedge);
    let (h_in, params_in, _) = engine.train(&mut run, Some(&eval), &cfg).unwrap();

    let weights = dar_weights(&ds.graph, &vc, Reweighting::Dar);
    let dir = std::env::temp_dir()
        .join(format!("cofree_dist_test_bf16_{}_{p}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dist::write_shards(&ds, &vc, &weights, seed, &dir).unwrap();
    let opts = ProcOptions {
        precision: Precision::Bf16,
        wire_codec: WireCodec::Bf16,
        wire_digests: true,
        ..ProcOptions::new(worker_bin())
    };
    let (h_proc, ck, stats) = dist::train_over_shards(&ds, &dir, &cfg, &opts, None).unwrap();
    std::fs::remove_dir_all(&dir).unwrap();

    assert_trajectories_identical(&h_in, &h_proc);
    assert_eq!(params_in.data, ck.params.data, "bf16 fleet final parameters diverged");
    // The compressed wire really was ~2x smaller than the f32 framing.
    assert!(
        stats.compression_ratio() >= 1.9,
        "bf16 codec ratio {:.3} below 1.9x",
        stats.compression_ratio()
    );
    assert!(stats.wire_compressed_bytes > 0 && stats.wire_raw_bytes > stats.wire_compressed_bytes);
    // And the compressed traffic beats the uncompressed bound.
    let f32_bound = (EXPECTED_F32_BYTES_PER_PARAM * p) as f64;
    assert!(
        stats.bytes_per_epoch_per_param() < f32_bound,
        "compressed traffic {} did not beat the f32 bound {f32_bound}",
        stats.bytes_per_epoch_per_param()
    );
}

/// The int8 codec on the default f32 tier is lossy by design: the fleet
/// must run to completion, produce finite parameters, and move ~4x fewer
/// tensor bytes — but nobody promises bit parity, so none is asserted.
#[test]
fn int8_codec_fleet_trains_and_compresses() {
    use cofree_gnn::dist::proto::WireCodec;
    let (p, seed, epochs) = (2usize, 47u64, 4usize);
    let ds = ds_small();
    let vc = cut(&ds, p, seed);
    let weights = dar_weights(&ds.graph, &vc, Reweighting::Dar);
    let dir = std::env::temp_dir()
        .join(format!("cofree_dist_test_int8_{}_{p}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dist::write_shards(&ds, &vc, &weights, seed, &dir).unwrap();
    let opts = ProcOptions {
        wire_codec: WireCodec::I8,
        ..ProcOptions::new(worker_bin())
    };
    let cfg = cfg_for(epochs, seed, None);
    let (h, ck, stats) = dist::train_over_shards(&ds, &dir, &cfg, &opts, None).unwrap();
    std::fs::remove_dir_all(&dir).unwrap();
    assert_eq!(h.epochs.len(), epochs);
    assert!(ck.params.data.iter().flatten().all(|x| x.is_finite()));
    assert!(
        stats.compression_ratio() >= 3.5,
        "int8 codec ratio {:.3} below 3.5x",
        stats.compression_ratio()
    );
}
